"""L1 Pallas kernel: fused fake-quant matmul.

The compute hot-spot of MobileNet under QAT is the pointwise-conv /
fully-connected matmul with quantize-dequantize on both operands. This
kernel fuses quantize(x) -> quantize(w) -> MXU matmul -> f32 accumulate
in one VMEM-resident pass, so quantized operands never round-trip to HBM
— the TPU analogue of the paper's bit-packing insight (fewer memory
transfers at lower precision). See DESIGN.md §Hardware-Adaptation.

TPU mapping (structural; executed under ``interpret=True`` on CPU PJRT —
the Mosaic path is compile-only in this environment):

* grid over M in ``BLOCK_M``-row stripes; each grid step holds an
  ``[BLOCK_M, K]`` x-tile, the full ``[K, N]`` w-panel and an
  ``[BLOCK_M, N]`` out-tile in VMEM;
* quantizer parameters (min/scale per tensor) are scalars computed once
  outside and broadcast into the kernel (SMEM-class operands);
* the multiply targets the MXU via ``jnp.dot`` with
  ``preferred_element_type=f32``.

Gradients: ``custom_vjp`` with straight-through estimation — the
backward pass uses the *dequantized* operands (plain jnp matmuls), and
bit-widths receive zero gradient.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quantize import qparams

# Default M-stripe. 128 matches the MXU systolic dimension; K and N panels
# are kept whole (the scaled MobileNet's K, N <= 256 fit VMEM comfortably:
# worst tile = (128*256 + 256*256 + 128*256) * 4 B ~ 0.5 MB << 16 MB VMEM).
BLOCK_M = 128


def _qmm_kernel(x_ref, w_ref, qp_ref, o_ref):
    """One grid step: o = fq(x_block) @ fq(w)."""
    qp = qp_ref[...]  # [4]: x_min, x_scale, w_min, w_scale
    x_min, x_scale, w_min, w_scale = qp[0], qp[1], qp[2], qp[3]
    x = x_ref[...]
    w = w_ref[...]
    xq = jnp.round((x - x_min) / x_scale) * x_scale + x_min
    wq = jnp.round((w - w_min) / w_scale) * w_scale + w_min
    o_ref[...] = jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def _qmatmul_impl(x, w, qa_bits, qw_bits, *, block_m=BLOCK_M, interpret=True):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"

    x_min, x_scale = qparams(x, qa_bits)
    w_min, w_scale = qparams(w, qw_bits)
    qp = jnp.stack([x_min, x_scale, w_min, w_scale]).astype(jnp.float32)

    bm = min(block_m, m)
    pad = (-m) % bm
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    mp = m + pad

    out = pl.pallas_call(
        _qmm_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),  # x stripe: HBM->VMEM
            pl.BlockSpec((k, n), lambda i: (0, 0)),  # w panel: resident
            pl.BlockSpec((4,), lambda i: (0,)),  # quant scalars
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=interpret,
    )(xp, w, qp)
    return out[:m] if pad else out


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def qmatmul(x, w, qa_bits, qw_bits):
    """Fake-quant matmul: ``fq(x, qa) @ fq(w, qw)``, STE gradients.

    x: [M, K] f32; w: [K, N] f32; qa_bits/qw_bits: f32 scalars (traced —
    runtime inputs in the AOT artifact).
    """
    return _qmatmul_impl(x, w, qa_bits, qw_bits)


def _fwd(x, w, qa_bits, qw_bits):
    out = _qmatmul_impl(x, w, qa_bits, qw_bits)
    return out, (x, w, qa_bits, qw_bits)


def _bwd(res, g):
    x, w, qa_bits, qw_bits = res
    # STE: d/dx [fq(x) @ fq(w)] ~= g @ fq(w)^T, d/dw ~= fq(x)^T @ g
    from ..quantize import quant_dequant

    xq = quant_dequant(x, qa_bits)
    wq = quant_dequant(w, qw_bits)
    gx = jnp.matmul(g, wq.T, preferred_element_type=jnp.float32)
    gw = jnp.matmul(xq.T, g, preferred_element_type=jnp.float32)
    return gx, gw, jnp.zeros_like(qa_bits), jnp.zeros_like(qw_bits)


qmatmul.defvjp(_fwd, _bwd)
