//! Explicit FSM models of the engine's distributed state machines,
//! with bounded **exhaustive** exploration (polestar-style).
//!
//! The repo's load-bearing invariant — distributed, faulty, resumable
//! search stays bit-identical to serial — was guarded by *randomized*
//! stateful scripts (`tests/distributed_stateful.rs`), which sample
//! event interleavings. This module replaces sampling with **coverage
//! for small scopes**: each protocol is written down as a small,
//! enumerable [`Fsm`]; a BFS explorer ([`explore`]) walks *every*
//! interleaving up to a depth/state [`Budget`] with state-hash dedup;
//! and a [`Projection`] binds the model to the real implementation
//! (the SUT), checking the retraction invariant
//!
//! ```text
//! map_state(apply(x, e)) == step(map_state(x), e)
//! ```
//!
//! at every edge ([`conform`]). On divergence the failing event trace
//! is greedily minimized (the same budgeted shrink discipline as
//! `util::prop`), written out as a replayable counterexample script,
//! and the `obs` flight recorder is dumped — see
//! [`Violation::fail_with_script`].
//!
//! The models (std-only, no I/O):
//! * [`batch::BatchModel`] — one driver↔worker batch: outcomes with
//!   duplication/reorder (BFS order-coverage), early `done`, loss,
//!   bogus shard indices, refill.
//! * [`window::WindowModel`] — the pipelined connection window
//!   (`engine::remote::PipelineWindow` + one `BatchLedger` per job):
//!   send/send-failure, interleaved outcomes, stale frames, done,
//!   loss-with-drain, final sweep.
//! * [`journal::JournalModel`] — the append-only checkpoint journal:
//!   insert/save/compaction/torn-tail crash/resume.
//!
//! `tests/model_conformance.rs` drives each model against its SUT;
//! [`Product`] composes two models for cross-product coverage runs.

pub mod batch;
pub mod journal;
pub mod window;

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;

/// An explicit, enumerable finite state machine.
///
/// `step` must be **total**: applying an event that `events` does not
/// currently offer must be a self-loop (return the state unchanged),
/// so that a minimized trace — which may drop the event that enabled a
/// later one — still replays meaningfully.
///
/// `show_event`/`parse_event` define the model's line-oriented event
/// grammar: one event per line in a counterexample script, round-
/// trippable so a committed script replays exactly.
pub trait Fsm {
    type State: Clone + Eq + Hash + std::fmt::Debug;
    type Event: Clone + std::fmt::Debug;

    /// Model name — names the counterexample script file and its
    /// `model:` header line.
    fn name(&self) -> String;
    fn initial(&self) -> Self::State;
    /// Events enabled in `s` (the BFS branching). Deterministic order.
    fn events(&self, s: &Self::State) -> Vec<Self::Event>;
    /// Total transition function (self-loop on disabled events).
    fn step(&self, s: &Self::State, e: &Self::Event) -> Self::State;
    /// Safety invariant, checked at every reached state.
    fn invariant(&self, _s: &Self::State) -> Result<(), String> {
        Ok(())
    }
    fn show_event(&self, e: &Self::Event) -> String;
    fn parse_event(&self, line: &str) -> Option<Self::Event>;
}

/// Exploration bounds: BFS stops expanding below `max_depth` and
/// aborts node admission at `max_states` deduped states. Environment
/// overrides (`QMAP_MODEL_DEPTH`, `QMAP_MODEL_STATES`) let CI raise
/// the scope without touching code, mirroring `util::prop`'s
/// `QMAP_PROP_SEED`/`QMAP_PROP_CASES` discipline.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub max_depth: usize,
    pub max_states: usize,
}

impl Budget {
    pub fn new(max_depth: usize, max_states: usize) -> Budget {
        Budget {
            max_depth,
            max_states,
        }
    }

    /// Defaults overridden by `QMAP_MODEL_DEPTH` / `QMAP_MODEL_STATES`.
    pub fn from_env(max_depth: usize, max_states: usize) -> Budget {
        let get = |k: &str| -> Option<usize> {
            std::env::var(k).ok().and_then(|v| v.trim().parse().ok())
        };
        Budget {
            max_depth: get("QMAP_MODEL_DEPTH").unwrap_or(max_depth),
            max_states: get("QMAP_MODEL_STATES").unwrap_or(max_states),
        }
    }
}

/// What an exploration covered.
#[derive(Debug, Clone, Copy)]
pub struct Coverage {
    /// Deduped states reached (including the initial state).
    pub states: usize,
    /// Transitions taken (model `step` evaluations that were admitted).
    pub transitions: usize,
    /// Deepest BFS layer reached.
    pub deepest: usize,
    /// `true` iff the frontier was exhausted within `max_depth`
    /// without hitting the `max_states` cap — i.e. the coverage is
    /// *exhaustive* for the scope, not budget-truncated.
    pub complete: bool,
}

/// A trace that violates a model invariant or diverges from the SUT.
#[derive(Debug)]
pub struct Violation<E> {
    /// Events from the initial state to the failure, minimized when
    /// produced by [`explore`]/[`conform`].
    pub trace: Vec<E>,
    pub msg: String,
}

impl<E: Clone + std::fmt::Debug> Violation<E> {
    /// Report a violation the way `util::prop` reports a shrunk
    /// property failure: write the minimized trace as a replayable
    /// script (`model_cex_<name>.script` in the working directory —
    /// CI uploads it as an artifact), dump the `obs` flight recorder,
    /// and panic with replay instructions.
    pub fn fail_with_script<M: Fsm<Event = E>>(&self, m: &M) -> ! {
        let mut text = format!("model:{}\n", m.name());
        for e in &self.trace {
            text.push_str(&m.show_event(e));
            text.push('\n');
        }
        let script = format!("model_cex_{}.script", m.name());
        let wrote = std::fs::write(&script, &text)
            .map(|_| script.clone())
            .unwrap_or_else(|e| format!("<unwritable: {e}>"));
        let dump = crate::obs::ring::dump("model_divergence");
        panic!(
            "model '{}' violated after {} event(s): {}\n  trace:\n{}  \
             script: {wrote}\n  flight recorder: {dump:?}\n  \
             replay: QMAP_MODEL_REPLAY={script} cargo test --test model_conformance",
            m.name(),
            self.trace.len(),
            self.msg,
            self.trace
                .iter()
                .map(|e| format!("    {}\n", m.show_event(e)))
                .collect::<String>(),
        )
    }
}

/// Replay a trace on the model alone, checking the invariant at every
/// step. `Err((i, msg))`: the invariant failed after applying `i`
/// events.
pub fn replay<M: Fsm>(m: &M, trace: &[M::Event]) -> Result<M::State, (usize, String)> {
    let mut s = m.initial();
    m.invariant(&s).map_err(|e| (0, e))?;
    for (i, ev) in trace.iter().enumerate() {
        s = m.step(&s, ev);
        m.invariant(&s).map_err(|e| (i + 1, e))?;
    }
    Ok(s)
}

/// Budgeted greedy event-deletion to a 1-minimal failing trace — the
/// same shrink discipline as `util::prop::check_shrink` (suffix
/// truncation first, then single deletions, to a fixpoint or budget).
pub fn shrink_events<E: Clone>(
    mut trace: Vec<E>,
    mut fails: impl FnMut(&[E]) -> bool,
) -> Vec<E> {
    let mut budget = 2000usize;
    // suffix truncation: binary-chop the tail off while still failing
    loop {
        if budget == 0 || trace.len() <= 1 {
            break;
        }
        let half = trace.len() / 2;
        budget -= 1;
        if fails(&trace[..half]) {
            trace.truncate(half);
        } else {
            break;
        }
    }
    // single deletions to a fixpoint
    let mut progress = true;
    while progress && budget > 0 {
        progress = false;
        let mut i = 0;
        while i < trace.len() && budget > 0 {
            let mut cand = trace.clone();
            cand.remove(i);
            budget -= 1;
            if fails(&cand) {
                trace = cand;
                progress = true;
            } else {
                i += 1;
            }
        }
    }
    trace
}

/// Bounded exhaustive BFS over every event interleaving of `m`,
/// deduplicating on the state itself, checking the invariant at every
/// reached state. On violation the trace is reconstructed via parent
/// pointers and minimized.
pub fn explore<M: Fsm>(m: &M, budget: &Budget) -> Result<Coverage, Violation<M::Event>> {
    let init = m.initial();
    if let Err(msg) = m.invariant(&init) {
        return Err(Violation {
            trace: Vec::new(),
            msg,
        });
    }
    // state -> id; parents[id] = (parent id, event that reached it)
    let mut ids: HashMap<M::State, usize> = HashMap::new();
    let mut parents: Vec<Option<(usize, M::Event)>> = Vec::new();
    let mut states: Vec<M::State> = Vec::new();
    ids.insert(init.clone(), 0);
    parents.push(None);
    states.push(init);
    let mut queue: VecDeque<(usize, usize)> = VecDeque::new();
    queue.push_back((0, 0));
    let mut cov = Coverage {
        states: 1,
        transitions: 0,
        deepest: 0,
        complete: true,
    };
    while let Some((id, depth)) = queue.pop_front() {
        if depth >= budget.max_depth {
            continue;
        }
        let here = states[id].clone();
        for ev in m.events(&here) {
            let next = m.step(&here, &ev);
            cov.transitions += 1;
            if let Err(msg) = m.invariant(&next) {
                let mut trace = trace_to(&parents, id);
                trace.push(ev);
                let trace = shrink_events(trace, |t| replay(m, t).is_err());
                return Err(Violation { trace, msg });
            }
            if ids.contains_key(&next) {
                continue;
            }
            if states.len() >= budget.max_states {
                cov.complete = false;
                continue;
            }
            let nid = states.len();
            ids.insert(next.clone(), nid);
            parents.push(Some((id, ev)));
            states.push(next);
            cov.states += 1;
            cov.deepest = cov.deepest.max(depth + 1);
            queue.push_back((nid, depth + 1));
        }
    }
    Ok(cov)
}

fn trace_to<E: Clone>(parents: &[Option<(usize, E)>], mut id: usize) -> Vec<E> {
    let mut rev = Vec::new();
    while let Some((pid, ev)) = &parents[id] {
        rev.push(ev.clone());
        id = *pid;
    }
    rev.reverse();
    rev
}

/// Binds a model to its system-under-test. `apply` drives the real
/// implementation with one model event and is the place to check
/// SUT-internal consistency (API return values, bit-identity against
/// a serial reference); `map_state` projects the SUT back into the
/// model's state space from *observables*.
pub trait Projection {
    type Model: Fsm;
    type Sut: Clone;

    fn model(&self) -> &Self::Model;
    fn init_sut(&self) -> Self::Sut;
    fn apply(
        &self,
        sut: &mut Self::Sut,
        e: &<Self::Model as Fsm>::Event,
    ) -> Result<(), String>;
    fn map_state(&self, sut: &Self::Sut) -> <Self::Model as Fsm>::State;
}

/// Replay a trace through model *and* SUT, checking the retraction
/// invariant after every event. `Err((i, msg))`: divergence after
/// applying `i + 1` events (or `i == usize::MAX` for a bad initial
/// projection).
pub fn replay_conformance<P: Projection>(
    p: &P,
    trace: &[<P::Model as Fsm>::Event],
) -> Result<(), (usize, String)> {
    let m = p.model();
    let mut s = m.initial();
    let mut sut = p.init_sut();
    if p.map_state(&sut) != s {
        return Err((usize::MAX, "initial projection mismatch".to_string()));
    }
    for (i, ev) in trace.iter().enumerate() {
        s = m.step(&s, ev);
        if let Err(e) = m.invariant(&s) {
            return Err((i, format!("model invariant: {e}")));
        }
        if let Err(e) = p.apply(&mut sut, ev) {
            return Err((i, format!("SUT rejected event: {e}")));
        }
        let projected = p.map_state(&sut);
        if projected != s {
            return Err((
                i,
                format!("retraction mismatch:\n  model {s:?}\n  SUT   {projected:?}"),
            ));
        }
    }
    Ok(())
}

/// Bounded exhaustive conformance run: BFS over every interleaving,
/// carrying `(model state, SUT)` pairs, checking
/// `map_state(apply(x, e)) == step(map_state(x), e)` at every edge.
///
/// Nodes are deduplicated on the **model** state alone. That is sound
/// for finding a *first* divergence: as long as every explored edge
/// satisfied the retraction invariant, any two SUTs mapping to the
/// same model state are interchangeable one edge further — and the
/// first edge where they are not is itself reported.
pub fn conform<P: Projection>(
    p: &P,
    budget: &Budget,
) -> Result<Coverage, Violation<<P::Model as Fsm>::Event>> {
    let m = p.model();
    let init = m.initial();
    let sut0 = p.init_sut();
    let minimize = |trace: Vec<<P::Model as Fsm>::Event>| {
        shrink_events(trace, |t| replay_conformance(p, t).is_err())
    };
    if let Err(msg) = m.invariant(&init) {
        return Err(Violation {
            trace: Vec::new(),
            msg,
        });
    }
    let projected = p.map_state(&sut0);
    if projected != init {
        return Err(Violation {
            trace: Vec::new(),
            msg: format!(
                "initial projection mismatch:\n  model {init:?}\n  SUT   {projected:?}"
            ),
        });
    }
    let mut ids: HashMap<<P::Model as Fsm>::State, usize> = HashMap::new();
    let mut parents: Vec<Option<(usize, <P::Model as Fsm>::Event)>> = Vec::new();
    let mut states: Vec<<P::Model as Fsm>::State> = Vec::new();
    let mut suts: Vec<P::Sut> = Vec::new();
    ids.insert(init.clone(), 0);
    parents.push(None);
    states.push(init);
    suts.push(sut0);
    let mut queue: VecDeque<(usize, usize)> = VecDeque::new();
    queue.push_back((0, 0));
    let mut cov = Coverage {
        states: 1,
        transitions: 0,
        deepest: 0,
        complete: true,
    };
    while let Some((id, depth)) = queue.pop_front() {
        if depth >= budget.max_depth {
            continue;
        }
        let here = states[id].clone();
        for ev in m.events(&here) {
            let next = m.step(&here, &ev);
            cov.transitions += 1;
            let fail = |msg: String| -> Violation<<P::Model as Fsm>::Event> {
                let mut trace = trace_to(&parents, id);
                trace.push(ev.clone());
                Violation {
                    trace: minimize(trace),
                    msg,
                }
            };
            if let Err(e) = m.invariant(&next) {
                return Err(fail(format!("model invariant: {e}")));
            }
            let mut sut = suts[id].clone();
            if let Err(e) = p.apply(&mut sut, &ev) {
                return Err(fail(format!("SUT rejected event: {e}")));
            }
            let projected = p.map_state(&sut);
            if projected != next {
                return Err(fail(format!(
                    "retraction mismatch:\n  model {next:?}\n  SUT   {projected:?}"
                )));
            }
            if ids.contains_key(&next) {
                continue;
            }
            if states.len() >= budget.max_states {
                cov.complete = false;
                continue;
            }
            let nid = states.len();
            ids.insert(next.clone(), nid);
            parents.push(Some((id, ev)));
            states.push(next);
            suts.push(sut);
            cov.states += 1;
            cov.deepest = cov.deepest.max(depth + 1);
            queue.push_back((nid, depth + 1));
        }
    }
    Ok(cov)
}

/// Parse a counterexample script produced by
/// [`Violation::fail_with_script`] back into a trace for `m`. Line 1
/// must be `model:<name>`; each later non-empty, non-`#` line is one
/// event in `m`'s grammar.
pub fn parse_script<M: Fsm>(m: &M, text: &str) -> Result<Vec<M::Event>, String> {
    let mut lines = text.lines();
    let head = lines.next().ok_or("empty script")?;
    let name = head
        .strip_prefix("model:")
        .ok_or("script missing 'model:' header line")?;
    if name != m.name() {
        return Err(format!("script is for model '{name}', not '{}'", m.name()));
    }
    let mut trace = Vec::new();
    for l in lines {
        let l = l.trim();
        if l.is_empty() || l.starts_with('#') {
            continue;
        }
        trace.push(
            m.parse_event(l)
                .ok_or_else(|| format!("unparseable event '{l}'"))?,
        );
    }
    Ok(trace)
}

/// Asynchronous product of two models: interleaves their events (no
/// synchronization), prefixing the event grammar with `a:` / `b:`.
/// Used for composed coverage runs (e.g. pipelining × journal).
pub struct Product<'a, A: Fsm, B: Fsm> {
    pub a: &'a A,
    pub b: &'a B,
}

/// A product event: one side moves, the other stands still.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Either<A, B> {
    L(A),
    R(B),
}

impl<'x, A: Fsm, B: Fsm> Fsm for Product<'x, A, B> {
    type State = (A::State, B::State);
    type Event = Either<A::Event, B::Event>;

    fn name(&self) -> String {
        format!("{}_x_{}", self.a.name(), self.b.name())
    }

    fn initial(&self) -> Self::State {
        (self.a.initial(), self.b.initial())
    }

    fn events(&self, s: &Self::State) -> Vec<Self::Event> {
        let mut evs: Vec<Self::Event> =
            self.a.events(&s.0).into_iter().map(Either::L).collect();
        evs.extend(self.b.events(&s.1).into_iter().map(Either::R));
        evs
    }

    fn step(&self, s: &Self::State, e: &Self::Event) -> Self::State {
        match e {
            Either::L(ea) => (self.a.step(&s.0, ea), s.1.clone()),
            Either::R(eb) => (s.0.clone(), self.b.step(&s.1, eb)),
        }
    }

    fn invariant(&self, s: &Self::State) -> Result<(), String> {
        self.a.invariant(&s.0).map_err(|e| format!("left: {e}"))?;
        self.b.invariant(&s.1).map_err(|e| format!("right: {e}"))
    }

    fn show_event(&self, e: &Self::Event) -> String {
        match e {
            Either::L(ea) => format!("a:{}", self.a.show_event(ea)),
            Either::R(eb) => format!("b:{}", self.b.show_event(eb)),
        }
    }

    fn parse_event(&self, line: &str) -> Option<Self::Event> {
        if let Some(rest) = line.strip_prefix("a:") {
            return self.a.parse_event(rest).map(Either::L);
        }
        line.strip_prefix("b:")
            .and_then(|rest| self.b.parse_event(rest))
            .map(Either::R)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A counter that may tick up to `cap`; the invariant bounds it at
    /// `bug_at` to exercise the violation/minimization path.
    struct Counter {
        cap: u32,
        bug_at: u32,
    }

    impl Fsm for Counter {
        type State = u32;
        type Event = char;

        fn name(&self) -> String {
            "counter".to_string()
        }
        fn initial(&self) -> u32 {
            0
        }
        fn events(&self, s: &u32) -> Vec<char> {
            if *s < self.cap {
                vec!['i', 'n']
            } else {
                Vec::new()
            }
        }
        fn step(&self, s: &u32, e: &char) -> u32 {
            match e {
                'i' if *s < self.cap => s + 1,
                _ => *s,
            }
        }
        fn invariant(&self, s: &u32) -> Result<(), String> {
            if *s >= self.bug_at {
                Err(format!("counter reached {s}"))
            } else {
                Ok(())
            }
        }
        fn show_event(&self, e: &char) -> String {
            e.to_string()
        }
        fn parse_event(&self, line: &str) -> Option<char> {
            let mut cs = line.chars();
            match (cs.next(), cs.next()) {
                (Some(c), None) => Some(c),
                _ => None,
            }
        }
    }

    #[test]
    fn explore_is_exhaustive_and_deduped() {
        let m = Counter {
            cap: 5,
            bug_at: u32::MAX,
        };
        let cov = explore(&m, &Budget::new(10, 1000)).expect("no violation");
        // states 0..=5, deduped across the 2^10 interleavings
        assert_eq!(cov.states, 6);
        assert!(cov.complete, "frontier must be exhausted");
        assert_eq!(cov.deepest, 5, "no-op self-loops dedup to depth 5");
    }

    #[test]
    fn explore_finds_and_minimizes_the_shortest_violation() {
        let m = Counter { cap: 10, bug_at: 3 };
        let v = explore(&m, &Budget::new(20, 10_000)).expect_err("must violate");
        // minimal trace: three increments, the no-op 'n' events shrunk away
        assert_eq!(v.trace, vec!['i', 'i', 'i']);
    }

    #[test]
    fn budget_truncation_is_reported_not_silent() {
        let m = Counter {
            cap: 50,
            bug_at: u32::MAX,
        };
        let cov = explore(&m, &Budget::new(100, 10)).expect("no violation");
        assert!(!cov.complete, "state cap must mark coverage incomplete");
        assert_eq!(cov.states, 10);
    }

    #[test]
    fn scripts_round_trip_through_the_grammar() {
        let m = Counter { cap: 4, bug_at: 3 };
        let v = explore(&m, &Budget::new(10, 100)).expect_err("must violate");
        let text = format!(
            "model:counter\n{}",
            v.trace
                .iter()
                .map(|e| format!("{}\n", m.show_event(e)))
                .collect::<String>()
        );
        let back = parse_script(&m, &text).expect("parse");
        assert_eq!(back, v.trace);
        assert!(parse_script(&m, "model:other\ni\n").is_err());
    }

    #[test]
    fn product_interleaves_both_sides() {
        let a = Counter {
            cap: 2,
            bug_at: u32::MAX,
        };
        let b = Counter {
            cap: 3,
            bug_at: u32::MAX,
        };
        let p = Product { a: &a, b: &b };
        let cov = explore(&p, &Budget::new(10, 10_000)).expect("no violation");
        assert_eq!(cov.states, 3 * 4, "product state space is the cross product");
        assert!(cov.complete);
        let ev = p.parse_event("b:i").expect("prefixed grammar");
        assert_eq!(p.show_event(&ev), "b:i");
    }
}
