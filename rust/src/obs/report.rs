//! `qmap trace-report FILE`: summarize a JSONL trace into per-layer
//! reject-rate/latency/cache tables — the human entry point into a
//! trace, and the raw material the ROADMAP's learned-guidance item
//! needs (per-workload validity rates, stage costs, cache reuse).

use crate::util::json::{parse, Json};
use std::collections::BTreeMap;

#[derive(Default)]
struct LayerAgg {
    jobs: u64,
    refs: u64,
    shards: u64,
    draws: u64,
    valid: u64,
    spatial_rejects: u64,
    tile_rejects: u64,
    job_us: f64,
}

#[derive(Default)]
struct AddrAgg {
    sent: u64,
    done: u64,
    lost: u64,
    rtt_us: f64,
    serve_us: f64,
    depth_eff: f64,
}

fn num(v: &Json, key: &str) -> f64 {
    v.get(key).as_f64().unwrap_or(0.0)
}

fn name(v: &Json, key: &str) -> String {
    v.get(key).as_str().unwrap_or("?").to_string()
}

/// Parse a trace produced by `--trace` (or a flight-recorder dump) and
/// render the summary tables. Unknown event kinds are skipped, so
/// reports stay total across schema additions; a line that is not
/// JSON at all is an error naming the line number.
pub fn report(src: &str) -> Result<String, String> {
    let mut schema: Option<f64> = None;
    let mut events = 0u64;
    let mut layers: BTreeMap<String, LayerAgg> = BTreeMap::new();
    let mut addrs: BTreeMap<String, AddrAgg> = BTreeMap::new();
    let mut gens = 0u64;
    let (mut pairs, mut unique, mut hits, mut misses, mut steals, mut splits) =
        (0u64, 0u64, 0u64, 0u64, 0u64, 0u64);
    let mut tail_ms = 0.0f64;
    let (mut appends, mut append_entries, mut write_us, mut fsync_us, mut compactions) =
        (0u64, 0u64, 0.0f64, 0.0f64, 0u64);
    let mut dumps = 0u64;
    let mut panics = 0u64;
    let mut proto_errors = 0u64;
    let mut lost_workers = 0u64;

    for (ln, line) in src.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = parse(line).map_err(|e| format!("line {}: {e}", ln + 1))?;
        events += 1;
        let kind = v.get("event").as_str().unwrap_or("");
        match kind {
            "trace_start" | "flightrec_dump" => {
                schema = v.get("schema").as_f64().or(schema);
            }
            "job" => {
                let l = layers.entry(name(&v, "layer")).or_default();
                l.jobs += 1;
                l.refs += num(&v, "refs") as u64;
                l.job_us += num(&v, "us");
            }
            "shard" => {
                let l = layers.entry(name(&v, "layer")).or_default();
                l.shards += 1;
                l.draws += num(&v, "draws") as u64;
                l.valid += num(&v, "valid") as u64;
                l.spatial_rejects += num(&v, "spatial_rejects") as u64;
                l.tile_rejects += num(&v, "tile_rejects") as u64;
            }
            "gen_eval" => {
                gens += 1;
                pairs += num(&v, "pairs") as u64;
                unique += num(&v, "unique_jobs") as u64;
                hits += num(&v, "cache_hits") as u64;
                misses += num(&v, "cache_misses") as u64;
                steals += num(&v, "steals") as u64;
                splits += num(&v, "splits") as u64;
                tail_ms += num(&v, "tail_ms");
            }
            "batch_sent" => {
                addrs.entry(name(&v, "addr")).or_default().sent += 1;
            }
            "batch_done" => {
                let a = addrs.entry(name(&v, "addr")).or_default();
                a.done += 1;
                a.rtt_us += num(&v, "rtt_us");
                a.serve_us += num(&v, "serve_us");
                a.depth_eff = num(&v, "depth_eff");
            }
            "worker_lost" => {
                lost_workers += 1;
                addrs.entry(name(&v, "addr")).or_default().lost += 1;
            }
            "proto_error" => proto_errors += 1,
            "ckpt_append" => {
                appends += 1;
                append_entries += num(&v, "entries") as u64;
                write_us += num(&v, "write_us");
                fsync_us += num(&v, "fsync_us");
            }
            "ckpt_compact" => compactions += 1,
            "panic" => panics += 1,
            _ => {}
        }
        if kind == "flightrec_dump" {
            dumps += 1;
        }
    }
    if events == 0 {
        return Err("empty trace (no events)".into());
    }

    let mut out = String::new();
    out.push_str(&format!(
        "trace: {events} event(s), schema {}\n",
        schema.map(|s| s.to_string()).unwrap_or_else(|| "?".into())
    ));
    if gens > 0 {
        let probes = hits + misses;
        out.push_str(&format!(
            "\ngenerations: {gens}  (jobs: {pairs} pair(s) -> {unique} unique, dedup {:.1}%; \
             cache hit rate {:.1}%; steals {steals}, splits {splits}; mean tail {:.1} ms)\n",
            if pairs > 0 { 100.0 * (1.0 - unique as f64 / pairs as f64) } else { 0.0 },
            if probes > 0 { 100.0 * hits as f64 / probes as f64 } else { 0.0 },
            tail_ms / gens as f64,
        ));
    }
    if !layers.is_empty() {
        out.push_str(&format!(
            "\n{:<14} {:>5} {:>5} {:>7} {:>11} {:>8} {:>9} {:>9} {:>9} {:>10}\n",
            "layer",
            "jobs",
            "refs",
            "shards",
            "draws",
            "valid",
            "reject%",
            "spatial%",
            "tile%",
            "job ms"
        ));
        for (name, l) in &layers {
            let d = l.draws.max(1) as f64;
            out.push_str(&format!(
                "{:<14} {:>5} {:>5} {:>7} {:>11} {:>8} {:>8.1}% {:>8.1}% {:>8.1}% {:>10.2}\n",
                name,
                l.jobs,
                l.refs,
                l.shards,
                l.draws,
                l.valid,
                100.0 * (1.0 - l.valid as f64 / d),
                100.0 * l.spatial_rejects as f64 / d,
                100.0 * l.tile_rejects as f64 / d,
                l.job_us / 1e3 / l.jobs.max(1) as f64,
            ));
        }
    }
    if !addrs.is_empty() {
        out.push_str(&format!(
            "\n{:<22} {:>6} {:>6} {:>5} {:>11} {:>11} {:>6}\n",
            "worker", "sent", "done", "lost", "rtt ms", "serve ms", "depth"
        ));
        for (addr, a) in &addrs {
            out.push_str(&format!(
                "{:<22} {:>6} {:>6} {:>5} {:>11.2} {:>11.2} {:>6.0}\n",
                addr,
                a.sent,
                a.done,
                a.lost,
                a.rtt_us / 1e3 / a.done.max(1) as f64,
                a.serve_us / 1e3 / a.done.max(1) as f64,
                a.depth_eff,
            ));
        }
    }
    if appends > 0 || compactions > 0 {
        out.push_str(&format!(
            "\ncheckpoint: {appends} append(s) ({append_entries} entr(ies); mean write {:.2} ms, \
             fsync {:.2} ms), {compactions} compaction(s)\n",
            write_us / 1e3 / appends.max(1) as f64,
            fsync_us / 1e3 / appends.max(1) as f64,
        ));
    }
    if panics + proto_errors + lost_workers + dumps > 0 {
        out.push_str(&format!(
            "\nfaults: {panics} panic(s), {proto_errors} protocol error(s), \
             {lost_workers} lost worker(s), {dumps} flight-recorder dump(s)\n"
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_aggregates_per_layer_and_per_worker() {
        let src = r#"{"event":"trace_start","schema":1,"seq":0,"t_us":0}
{"event":"job","layer":"c1","refs":3,"us":1500,"seq":1,"t_us":10}
{"event":"shard","layer":"c1","draws":100,"valid":10,"spatial_rejects":60,"tile_rejects":30,"seq":2,"t_us":20}
{"event":"shard","layer":"c1","draws":100,"valid":20,"spatial_rejects":50,"tile_rejects":30,"seq":3,"t_us":30}
{"event":"gen_eval","pairs":8,"unique_jobs":4,"cache_hits":3,"cache_misses":1,"steals":2,"splits":1,"tail_ms":5.0,"seq":4,"t_us":40}
{"event":"batch_sent","addr":"127.0.0.1:7911","batch":1,"seq":5,"t_us":50}
{"event":"batch_done","addr":"127.0.0.1:7911","batch":1,"rtt_us":2000,"serve_us":1000,"depth_eff":3,"seq":6,"t_us":60}
{"event":"ckpt_append","entries":16,"write_us":100,"fsync_us":900,"seq":7,"t_us":70}
"#;
        let rep = report(src).expect("report");
        assert!(rep.contains("schema 1"), "{rep}");
        assert!(rep.contains("c1"), "{rep}");
        // 200 draws, 30 valid -> 85% reject
        assert!(rep.contains("85.0%"), "{rep}");
        assert!(rep.contains("127.0.0.1:7911"), "{rep}");
        assert!(rep.contains("dedup 50.0%"), "{rep}");
        assert!(rep.contains("hit rate 75.0%"), "{rep}");
        assert!(rep.contains("1 append(s)"), "{rep}");
    }

    #[test]
    fn report_rejects_non_json_and_empty_traces() {
        assert!(report("").is_err());
        let err = report("{\"event\":\"x\"}\nnot json\n").unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
    }

    #[test]
    fn unknown_event_kinds_are_skipped() {
        let rep = report("{\"event\":\"from_the_future\",\"seq\":0}\n").expect("total");
        assert!(rep.contains("1 event(s)"), "{rep}");
    }
}
