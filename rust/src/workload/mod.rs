//! DNN layer workloads in Timeloop's 7-dimensional convolution form.
//!
//! A convolutional layer is a 7-deep loop nest over
//! `N` (batch), `K` (output channels; Timeloop calls this `M`),
//! `C` (input channels), `R`/`S` (filter height/width),
//! `P`/`Q` (output height/width). Three data spaces are projected from
//! these dims: Weights `W[K,C,R,S]`, Inputs `I[N,C,H,W]`
//! (`H=(P-1)*stride+R` sliding window), Outputs `O[N,K,P,Q]`.
//!
//! Depthwise convolutions are modeled with `C = 1` and the input channel
//! dimension *tied to K* (each output channel reads its own input
//! channel), matching how Timeloop workloads for MobileNet are written.

pub mod models;
pub mod parser;

/// The seven problem dimensions, in canonical order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dim {
    N,
    K,
    C,
    R,
    S,
    P,
    Q,
}

pub const DIMS: [Dim; 7] = [Dim::N, Dim::K, Dim::C, Dim::R, Dim::S, Dim::P, Dim::Q];

impl Dim {
    pub const fn index(self) -> usize {
        match self {
            Dim::N => 0,
            Dim::K => 1,
            Dim::C => 2,
            Dim::R => 3,
            Dim::S => 4,
            Dim::P => 5,
            Dim::Q => 6,
        }
    }
    pub const fn name(self) -> &'static str {
        match self {
            Dim::N => "N",
            Dim::K => "K",
            Dim::C => "C",
            Dim::R => "R",
            Dim::S => "S",
            Dim::P => "P",
            Dim::Q => "Q",
        }
    }
    pub fn from_index(i: usize) -> Dim {
        DIMS[i]
    }
}

/// The three data spaces of a convolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tensor {
    Weights,
    Inputs,
    Outputs,
}

pub const TENSORS: [Tensor; 3] = [Tensor::Weights, Tensor::Inputs, Tensor::Outputs];

impl Tensor {
    pub const fn index(self) -> usize {
        match self {
            Tensor::Weights => 0,
            Tensor::Inputs => 1,
            Tensor::Outputs => 2,
        }
    }
    pub const fn name(self) -> &'static str {
        match self {
            Tensor::Weights => "Weights",
            Tensor::Inputs => "Inputs",
            Tensor::Outputs => "Outputs",
        }
    }
}

/// Layer kind; affects tensor projections.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Standard convolution (includes pointwise when R=S=1 and
    /// fully-connected when R=S=P=Q=1).
    Standard,
    /// Depthwise convolution: one filter per channel; we store the channel
    /// count in `K` and fix `C = 1`; Inputs are indexed by `K`.
    Depthwise,
}

/// One convolutional workload (a single layer of a network).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ConvLayer {
    pub name: String,
    pub kind: LayerKind,
    /// Dimension sizes indexed by `Dim::index()`: `[N, K, C, R, S, P, Q]`.
    pub dims: [u64; 7],
    pub stride: (u64, u64),
}

impl ConvLayer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        kind: LayerKind,
        n: u64,
        k: u64,
        c: u64,
        r: u64,
        s: u64,
        p: u64,
        q: u64,
        stride: (u64, u64),
    ) -> Self {
        let c = if kind == LayerKind::Depthwise { 1 } else { c };
        assert!(n * k * c * r * s * p * q > 0, "zero-sized layer {name}");
        ConvLayer {
            name: name.to_string(),
            kind,
            dims: [n, k, c, r, s, p, q],
            stride,
        }
    }

    /// Standard conv helper from (in_ch, out_ch, filter, out_spatial).
    pub fn conv(name: &str, c: u64, k: u64, r: u64, p: u64, stride: u64) -> Self {
        ConvLayer::new(name, LayerKind::Standard, 1, k, c, r, r, p, p, (stride, stride))
    }

    /// Depthwise conv helper.
    pub fn dw(name: &str, ch: u64, r: u64, p: u64, stride: u64) -> Self {
        ConvLayer::new(name, LayerKind::Depthwise, 1, ch, 1, r, r, p, p, (stride, stride))
    }

    /// Pointwise (1x1) conv helper.
    pub fn pw(name: &str, c: u64, k: u64, p: u64) -> Self {
        ConvLayer::new(name, LayerKind::Standard, 1, k, c, 1, 1, p, p, (1, 1))
    }

    /// Fully-connected layer as a 1x1x1 conv.
    pub fn fc(name: &str, c: u64, k: u64) -> Self {
        ConvLayer::new(name, LayerKind::Standard, 1, k, c, 1, 1, 1, 1, (1, 1))
    }

    pub fn size(&self, d: Dim) -> u64 {
        self.dims[d.index()]
    }

    /// Which dims index a tensor (its "relevant" / coupled dims).
    pub fn coupled_dims(&self, t: Tensor) -> Vec<Dim> {
        match (t, self.kind) {
            (Tensor::Weights, LayerKind::Standard) => vec![Dim::K, Dim::C, Dim::R, Dim::S],
            (Tensor::Weights, LayerKind::Depthwise) => vec![Dim::K, Dim::R, Dim::S],
            (Tensor::Inputs, LayerKind::Standard) => {
                vec![Dim::N, Dim::C, Dim::R, Dim::S, Dim::P, Dim::Q]
            }
            (Tensor::Inputs, LayerKind::Depthwise) => {
                vec![Dim::N, Dim::K, Dim::R, Dim::S, Dim::P, Dim::Q]
            }
            (Tensor::Outputs, _) => vec![Dim::N, Dim::K, Dim::P, Dim::Q],
        }
    }

    /// True iff iterating `d` changes which elements of `t` are touched.
    pub fn is_relevant(&self, t: Tensor, d: Dim) -> bool {
        match (t, self.kind) {
            (Tensor::Weights, LayerKind::Standard) => {
                matches!(d, Dim::K | Dim::C | Dim::R | Dim::S)
            }
            (Tensor::Weights, LayerKind::Depthwise) => matches!(d, Dim::K | Dim::R | Dim::S),
            (Tensor::Inputs, LayerKind::Standard) => !matches!(d, Dim::K),
            (Tensor::Inputs, LayerKind::Depthwise) => !matches!(d, Dim::C),
            (Tensor::Outputs, _) => matches!(d, Dim::N | Dim::K | Dim::P | Dim::Q),
        }
    }

    /// Footprint in elements of a *tile* described by per-dim extents.
    /// Input spatial extents use the sliding-window formula.
    pub fn tile_elements(&self, t: Tensor, tile: &[u64; 7]) -> u64 {
        let g = |d: Dim| tile[d.index()];
        match (t, self.kind) {
            (Tensor::Weights, LayerKind::Standard) => g(Dim::K) * g(Dim::C) * g(Dim::R) * g(Dim::S),
            (Tensor::Weights, LayerKind::Depthwise) => g(Dim::K) * g(Dim::R) * g(Dim::S),
            (Tensor::Inputs, kind) => {
                let h = (g(Dim::P) - 1) * self.stride.0 + g(Dim::R);
                let w = (g(Dim::Q) - 1) * self.stride.1 + g(Dim::S);
                let ch = if kind == LayerKind::Depthwise {
                    g(Dim::K)
                } else {
                    g(Dim::C)
                };
                g(Dim::N) * ch * h * w
            }
            (Tensor::Outputs, _) => g(Dim::N) * g(Dim::K) * g(Dim::P) * g(Dim::Q),
        }
    }

    /// Total footprint in elements of the full tensor.
    pub fn tensor_elements(&self, t: Tensor) -> u64 {
        self.tile_elements(t, &self.dims)
    }

    /// Total multiply-accumulate operations for the layer.
    pub fn macs(&self) -> u64 {
        self.dims.iter().product()
    }

    /// Input feature-map spatial size implied by output size and stride.
    pub fn input_hw(&self) -> (u64, u64) {
        (
            (self.size(Dim::P) - 1) * self.stride.0 + self.size(Dim::R),
            (self.size(Dim::Q) - 1) * self.stride.1 + self.size(Dim::S),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_conv_footprints() {
        // 3x3 conv, C=16, K=32, 8x8 output, stride 1
        let l = ConvLayer::conv("c", 16, 32, 3, 8, 1);
        assert_eq!(l.tensor_elements(Tensor::Weights), 32 * 16 * 3 * 3);
        assert_eq!(l.tensor_elements(Tensor::Outputs), 32 * 8 * 8);
        assert_eq!(l.tensor_elements(Tensor::Inputs), 16 * 10 * 10);
        assert_eq!(l.macs(), 32 * 16 * 3 * 3 * 8 * 8);
    }

    #[test]
    fn depthwise_projections() {
        let l = ConvLayer::dw("d", 32, 3, 112, 1);
        assert_eq!(l.size(Dim::C), 1);
        assert_eq!(l.tensor_elements(Tensor::Weights), 32 * 3 * 3);
        // inputs indexed by K for depthwise
        assert_eq!(l.tensor_elements(Tensor::Inputs), 32 * 114 * 114);
        assert!(l.is_relevant(Tensor::Inputs, Dim::K));
        assert!(!l.is_relevant(Tensor::Inputs, Dim::C));
        assert!(l.is_relevant(Tensor::Weights, Dim::K));
    }

    #[test]
    fn pointwise_and_fc() {
        let l = ConvLayer::pw("p", 64, 128, 14);
        assert_eq!(l.tensor_elements(Tensor::Weights), 64 * 128);
        assert_eq!(l.tensor_elements(Tensor::Inputs), 64 * 14 * 14);
        let f = ConvLayer::fc("f", 1024, 1000);
        assert_eq!(f.tensor_elements(Tensor::Weights), 1024 * 1000);
        assert_eq!(f.tensor_elements(Tensor::Outputs), 1000);
        assert_eq!(f.macs(), 1024 * 1000);
    }

    #[test]
    fn strided_input_window() {
        let l = ConvLayer::dw("d", 8, 3, 56, 2);
        let (h, w) = l.input_hw();
        assert_eq!((h, w), (113, 113));
        // tile of one output row
        let mut tile = l.dims;
        tile[Dim::P.index()] = 1;
        let elems = l.tile_elements(Tensor::Inputs, &tile);
        assert_eq!(elems, 8 * 3 * 113);
    }

    #[test]
    fn relevance_vs_coupled_consistency() {
        for l in [
            ConvLayer::conv("c", 16, 32, 3, 8, 1),
            ConvLayer::dw("d", 32, 3, 112, 1),
        ] {
            for t in TENSORS {
                for d in DIMS {
                    let coupled = l.coupled_dims(t).contains(&d);
                    assert_eq!(coupled, l.is_relevant(t, d), "{t:?} {d:?}");
                }
            }
        }
    }
}
