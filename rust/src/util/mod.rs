//! Zero-dependency utilities: PRNG, JSON, stats, CLI parsing, and a mini
//! property-testing harness. These stand in for `rand`, `serde_json`,
//! `clap`, and `proptest`, none of which are available in the offline
//! build environment (see DESIGN.md §7).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Streaming FNV-1a (64-bit) — the one copy of the offset basis and
/// prime shared by the mapper's workload hash, the cache key, and the
/// wire-frame checksum, so they cannot drift apart. Not cryptographic.
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf29ce484222325)
    }

    /// Continue hashing from a previously finished state (used by the
    /// cache key, which extends the workload hash with the arch name).
    pub fn with_state(state: u64) -> Fnv1a {
        Fnv1a(state)
    }

    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    #[inline]
    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// FNV-1a of one byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write(bytes);
    h.finish()
}

/// Format a large count with thousands separators (report tables).
pub fn with_commas(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, c) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*c as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn fnv1a_reference_vectors() {
        // canonical FNV-1a 64 test vectors; pin the constants so the
        // three users (workload hash, cache key, frame checksum) can
        // never silently diverge
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
        // streaming in pieces equals one-shot
        let mut h = Fnv1a::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a(b"foobar"));
        // resuming from a state continues the same stream
        let mut r = Fnv1a::with_state(fnv1a(b"foo"));
        r.write(b"bar");
        assert_eq!(r.finish(), fnv1a(b"foobar"));
    }

    #[test]
    fn commas() {
        assert_eq!(with_commas(0), "0");
        assert_eq!(with_commas(999), "999");
        assert_eq!(with_commas(1000), "1,000");
        assert_eq!(with_commas(1234567), "1,234,567");
    }
}
