//! Fuzz-ish robustness properties for the two untrusted parsers: wire
//! frames (`engine::proto`) and checkpoint files
//! (`engine::checkpoint`). Property-generated corpus via `util::prop`:
//! truncated, bit-flipped, and oversize-length-prefix inputs must
//! return `Err` — never panic, never attempt an attacker-sized
//! allocation. Honors `QMAP_PROP_SEED` / `QMAP_PROP_CASES` for
//! replaying any reported failure.

use qmap::arch::presets::toy;
use qmap::engine::checkpoint::SearchIdent;
use qmap::engine::{proto, Checkpointer};
use qmap::mapper::cache::MapperCache;
use qmap::mapper::{MapperConfig, ShardOutcome, ShardSpec};
use qmap::nsga::{Individual, NsgaConfig, SearchState};
use qmap::quant::{LayerQuant, QuantConfig};
use qmap::util::json::Json;
use qmap::util::prop::{check, check_with_rng};
use qmap::util::rng::Rng;
use qmap::workload::ConvLayer;
use std::panic::{catch_unwind, AssertUnwindSafe};

// ------------------------------------------------------------ frames

fn random_payload(r: &mut Rng) -> Vec<u8> {
    let n = r.range(0, 300);
    (0..n).map(|_| r.below(256) as u8).collect()
}

#[test]
fn truncated_frames_always_error() {
    check_with_rng(
        0xF0A1,
        60,
        random_payload,
        |payload, r| {
            let framed = proto::encode_frame(payload);
            // any strict prefix must fail to decode
            let cut = r.range(0, framed.len() - 1);
            let mut cur = std::io::Cursor::new(framed[..cut].to_vec());
            match proto::read_frame(&mut cur) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("decoded a frame truncated at {cut}/{}", framed.len())),
            }
        },
    );
}

#[test]
fn bit_flipped_frames_always_error() {
    check_with_rng(
        0xF0A2,
        60,
        random_payload,
        |payload, r| {
            let framed = proto::encode_frame(payload);
            let byte = r.range(0, framed.len() - 1);
            let bit = r.range(0, 7);
            let mut bad = framed.clone();
            bad[byte] ^= 1 << bit;
            let mut cur = std::io::Cursor::new(bad);
            match proto::read_frame(&mut cur) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("accepted a frame with byte {byte} bit {bit} flipped")),
            }
        },
    );
}

#[test]
fn hostile_length_prefixes_never_allocate() {
    // every length above the cap must be rejected from the 16-byte
    // header alone — the payload buffer is never allocated, so even a
    // 4 GiB claim is a cheap, clean error
    check(
        0xF0A3,
        40,
        |r| (proto::MAX_FRAME as u64 + 1 + r.below(u32::MAX as u64 - proto::MAX_FRAME as u64)) as u32,
        |&len| {
            let mut framed = proto::encode_frame(b"x");
            framed[4..8].copy_from_slice(&len.to_be_bytes());
            let mut cur = std::io::Cursor::new(framed);
            match proto::read_frame(&mut cur) {
                Err(e) if e.contains("cap") => Ok(()),
                other => Err(format!("length {len} not rejected by the cap: {other:?}")),
            }
        },
    );
}

#[test]
fn random_garbage_streams_error() {
    check(
        0xF0A4,
        80,
        |r| {
            // random bytes that are (deliberately) not frame-magic
            let mut b = random_payload(r);
            if b.first() == Some(&b'Q') {
                b[0] = b'X';
            }
            b
        },
        |bytes| {
            let mut cur = std::io::Cursor::new(bytes.clone());
            match proto::read_frame(&mut cur) {
                Err(_) => Ok(()),
                Ok(_) => Err("decoded random garbage as a frame".into()),
            }
        },
    );
}

#[test]
fn valid_frames_with_malformed_json_error_cleanly() {
    // the frame layer passes, the message layer must still be total
    for payload in [
        &b"not json"[..],
        &b"{\"type\":"[..],
        &b"\xff\xfe\xfd"[..],                  // invalid UTF-8
        &b"{\"a\":1}{\"b\":2}"[..],            // trailing garbage
    ] {
        let framed = proto::encode_frame(payload);
        let mut cur = std::io::Cursor::new(framed);
        assert!(proto::read_msg(&mut cur).is_err(), "payload {payload:?}");
    }
    // pathological nesting is bounded by the JSON parser's depth cap
    let deep = "[".repeat(100_000);
    let framed = proto::encode_frame(deep.as_bytes());
    let mut cur = std::io::Cursor::new(framed);
    assert!(proto::read_msg(&mut cur).is_err());
}

// ------------------------------------------ structured wire payloads

/// A small random-JSON grammar for structure-level fuzzing of the
/// typed decoders.
fn random_json(r: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { r.below(4) } else { r.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(r.below(2) == 0),
        2 => Json::Num(f64::from_bits(r.next_u64())),
        3 => Json::Str(
            (0..r.range(0, 12))
                .map(|_| char::from(32 + r.below(95) as u8))
                .collect(),
        ),
        4 => Json::Arr((0..r.range(0, 4)).map(|_| random_json(r, depth - 1)).collect()),
        _ => Json::obj(
            ["seed", "valid_target", "max_draws", "best", "valid", "draws", "x"]
                .iter()
                .take(r.range(0, 6))
                .map(|k| (*k, random_json(r, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn typed_decoders_are_total_on_random_json() {
    check(
        0xF0A5,
        300,
        |r| random_json(r, 3),
        |v| {
            // none of these may panic; Err is the expected common case
            let _ = ShardSpec::from_json(v);
            let _ = ShardOutcome::from_json(v);
            let _ = proto::layer_from_json(v);
            let _ = proto::quant_from_json(v);
            Ok(())
        },
    );
}

// -------------------------------------------------------- checkpoint

fn tmp_path(tag: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("qmap_robust_{tag}_{}.json", std::process::id()));
    p.to_string_lossy().into_owned()
}

fn ident() -> SearchIdent {
    SearchIdent::new(
        &toy(),
        4,
        &qmap::objective::ObjectiveSpec::default(),
        &MapperConfig::default(),
        &NsgaConfig::default(),
    )
}

/// A realistic checkpoint document (population with infinite
/// objectives, advanced RNG, cache with positive and negative
/// entries), as raw bytes.
fn checkpoint_bytes() -> Vec<u8> {
    let path = tmp_path("seed");
    let ckpt = Checkpointer::new(path.as_str());
    let mut st = SearchState {
        generation: 2,
        pop: (0..3)
            .map(|i| Individual {
                genome: QuantConfig::uniform(4, 2 + i as u8),
                objectives: qmap::objective::ObjectiveVec::raw(vec![
                    if i == 0 { f64::INFINITY } else { 1.5e-9 * i as f64 },
                    0.25,
                ]),
            })
            .collect(),
        rng: Rng::new(0xFEED),
    };
    for _ in 0..9 {
        st.rng.next_u64();
    }
    let cache = MapperCache::new();
    let arch = toy();
    let cfg = MapperConfig {
        valid_target: 20,
        max_draws: 20_000,
        seed: 5,
        shards: 1,
    };
    cache.evaluate(&arch, &ConvLayer::fc("fc", 16, 10), &LayerQuant::uniform(8), &cfg);
    ckpt.save(&st, &cache, &ident()).expect("seed checkpoint");
    let bytes = std::fs::read(&path).expect("read seed checkpoint");
    let _ = std::fs::remove_file(&path);
    bytes
}

#[test]
fn truncated_checkpoints_error_or_recover_a_consistent_prefix() {
    // The journal tolerates a torn *final* line by design (crash
    // mid-append): such a truncation may load, but only to the state
    // of the last complete generation mark — never to garbage, and
    // never via a panic. Every other truncation must be a clean error.
    let bytes = checkpoint_bytes();
    check(
        0xF0B1,
        40,
        |r| r.range(0, bytes.len() - 1),
        |&cut| {
            let path = tmp_path(&format!("trunc{cut}"));
            std::fs::write(&path, &bytes[..cut]).map_err(|e| e.to_string())?;
            let ckpt = Checkpointer::new(path.as_str());
            let r = catch_unwind(AssertUnwindSafe(|| ckpt.load(&ident(), &MapperCache::new())));
            let _ = std::fs::remove_file(&path);
            match r {
                Ok(Err(_)) => Ok(()),
                Ok(Ok(st)) => {
                    // recoverable only when a complete mark survived —
                    // and then it must be exactly the saved state
                    if st.generation == 2 && st.pop.len() == 3 {
                        Ok(())
                    } else {
                        Err(format!(
                            "checkpoint truncated at {cut} loaded an inconsistent state \
                             (generation {}, population {})",
                            st.generation,
                            st.pop.len()
                        ))
                    }
                }
                Err(_) => Err(format!("panicked on a checkpoint truncated at {cut}")),
            }
        },
    );
}

#[test]
fn bit_flipped_checkpoints_never_panic() {
    // a flipped bit may still parse (e.g. inside a hex digit) — that
    // is fine; what is not fine is a panic or abort. The load path
    // must be total on arbitrary corruption.
    let bytes = checkpoint_bytes();
    check_with_rng(
        0xF0B2,
        60,
        |_| (),
        |_, r| {
            let byte = r.range(0, bytes.len() - 1);
            let bit = r.range(0, 7);
            let mut bad = bytes.clone();
            bad[byte] ^= 1 << bit;
            let path = tmp_path(&format!("flip{byte}_{bit}"));
            std::fs::write(&path, &bad).map_err(|e| e.to_string())?;
            let ckpt = Checkpointer::new(path.as_str());
            let r = catch_unwind(AssertUnwindSafe(|| ckpt.load(&ident(), &MapperCache::new())));
            let _ = std::fs::remove_file(&path);
            match r {
                Ok(_) => Ok(()),
                Err(_) => Err(format!("panicked on checkpoint with byte {byte} bit {bit} flipped")),
            }
        },
    );
}

#[test]
fn pathological_checkpoint_nesting_is_rejected() {
    let path = tmp_path("deepnest");
    let deep = format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000));
    std::fs::write(&path, deep).unwrap();
    let ckpt = Checkpointer::new(path.as_str());
    let r = catch_unwind(AssertUnwindSafe(|| ckpt.load(&ident(), &MapperCache::new())));
    let _ = std::fs::remove_file(&path);
    assert!(matches!(r, Ok(Err(_))), "deep nesting must be a clean error");
}
