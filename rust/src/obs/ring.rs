//! The always-on flight-recorder ring: a bounded buffer of the last
//! [`RING_CAPACITY`] rendered event lines, dumped to a JSONL file when
//! something goes wrong (panic, lost worker, protocol error). The ring
//! is process-global and cheap enough to leave on unconditionally —
//! one mutex push per event, no I/O until a dump is triggered.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Events retained for post-mortem dumps. Old events are overwritten;
/// the dump header records how many were dropped.
pub const RING_CAPACITY: usize = 1024;

/// How many dump paths [`recent_dumps`] remembers (oldest evicted).
const DUMP_LOG: usize = 32;

struct Ring {
    buf: Vec<String>,
    /// Next overwrite position once `buf` is full.
    next: usize,
    /// Total events ever pushed (so a dump can report drops).
    total: u64,
}

static RING: Mutex<Ring> = Mutex::new(Ring {
    buf: Vec::new(),
    next: 0,
    total: 0,
});
static DUMPS: Mutex<Vec<PathBuf>> = Mutex::new(Vec::new());
static DUMP_SEQ: AtomicU64 = AtomicU64::new(0);

pub(crate) fn push(line: String) {
    let mut r = RING.lock().unwrap();
    if r.buf.len() < RING_CAPACITY {
        r.buf.push(line);
    } else {
        let i = r.next;
        r.buf[i] = line;
        r.next = (r.next + 1) % RING_CAPACITY;
    }
    r.total += 1;
}

/// The ring's contents, oldest to newest.
pub fn snapshot() -> Vec<String> {
    let r = RING.lock().unwrap();
    let mut out = Vec::with_capacity(r.buf.len());
    if r.buf.len() < RING_CAPACITY {
        out.extend(r.buf.iter().cloned());
    } else {
        out.extend(r.buf[r.next..].iter().cloned());
        out.extend(r.buf[..r.next].iter().cloned());
    }
    out
}

/// Dump the ring to a fresh JSONL file in the temp directory (header
/// line naming the trigger `reason` and the schema version, then the
/// retained events oldest-first). Returns the path, also remembered in
/// [`recent_dumps`] so tests and post-mortems can find it without an
/// env-var side channel. Returns `None` only if the file can't be
/// written — forensics must never take the process down.
pub fn dump(reason: &str) -> Option<PathBuf> {
    let seq = DUMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let lines = snapshot();
    let total = RING.lock().unwrap().total;
    let mut path = std::env::temp_dir();
    // keep the reason out of the filename untrusted-input-safe
    let tag: String =
        reason.chars().filter(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
    path.push(format!("qmap-flightrec-{}-{seq}-{tag}.jsonl", std::process::id()));
    let mut out = String::with_capacity(lines.iter().map(|l| l.len() + 1).sum::<usize>() + 128);
    out.push_str(
        &crate::util::json::Json::obj(vec![
            ("event", crate::util::json::Json::Str("flightrec_dump".into())),
            ("reason", crate::util::json::Json::Str(reason.into())),
            ("schema", crate::util::json::Json::Num(super::SCHEMA_VERSION as f64)),
            ("events", crate::util::json::Json::Num(lines.len() as f64)),
            (
                "dropped",
                crate::util::json::Json::Num(total.saturating_sub(lines.len() as u64) as f64),
            ),
        ])
        .to_string(),
    );
    out.push('\n');
    for l in &lines {
        out.push_str(l);
        out.push('\n');
    }
    if std::fs::write(&path, out).is_err() {
        return None;
    }
    super::metrics::counters().dumps.fetch_add(1, Ordering::Relaxed);
    let mut log = DUMPS.lock().unwrap();
    if log.len() >= DUMP_LOG {
        log.remove(0);
    }
    log.push(path.clone());
    Some(path)
}

/// The last few dump paths, oldest first. Process-global: fault tests
/// scan these for the dump their injected failure produced.
pub fn recent_dumps() -> Vec<PathBuf> {
    DUMPS.lock().unwrap().clone()
}
