//! §Observability acceptance: attaching a JSONL trace must never move
//! a search result — the recorder observes, it does not participate.
//! The same engine/run with tracing on and off must produce
//! bit-identical Pareto fronts, and the trace itself must be valid
//! schema-versioned JSONL that `qmap trace-report` can summarize.

use qmap::accuracy::{ProxyAccuracy, ProxyParams};
use qmap::arch::presets::toy;
use qmap::baselines::search_with_objectives;
use qmap::engine::Engine;
use qmap::mapper::cache::MapperCache;
use qmap::mapper::MapperConfig;
use qmap::nsga::NsgaConfig;
use qmap::objective::ObjectiveSpec;
use qmap::util::json::parse;
use qmap::workload::ConvLayer;
use std::sync::Mutex;

/// The trace sink is process-global: tests that attach one serialize
/// through this lock so a concurrent test's events cannot interleave.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn small_net() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("c1", 3, 8, 3, 16, 1),
        ConvLayer::dw("d1", 8, 3, 16, 1),
        ConvLayer::pw("p1", 8, 16, 16),
        ConvLayer::fc("fc", 16, 10),
    ]
}

/// One full (small) NSGA-II search on the given engine, reduced to a
/// sorted front key: (encoded genome, EDP bits) — the same comparison
/// the distributed bit-identity suite uses.
fn run_front(engine: &Engine) -> Vec<(Vec<u8>, u64)> {
    let arch = toy();
    let layers = small_net();
    let map_cfg = MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 53,
        shards: 2,
    };
    let nsga_cfg = NsgaConfig {
        population: 8,
        offspring: 4,
        generations: 3,
        seed: 59,
        ..NsgaConfig::default()
    };
    let spec = ObjectiveSpec::default();
    let cache = MapperCache::new();
    let mut acc = ProxyAccuracy::new(&layers, ProxyParams::default());
    let cands = search_with_objectives(
        engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec, |_, _| {},
    );
    let mut k: Vec<(Vec<u8>, u64)> = cands
        .iter()
        .map(|c| (c.genome.encode(), c.hw.edp.to_bits()))
        .collect();
    k.sort();
    k
}

#[test]
fn tracing_on_vs_off_yields_bit_identical_fronts_and_a_valid_trace() {
    let _guard = TRACE_LOCK.lock().unwrap();
    let mut p = std::env::temp_dir();
    p.push(format!("qmap_obs_trace_{}.jsonl", std::process::id()));
    let path = p.to_string_lossy().into_owned();

    let untraced = run_front(&Engine::new(2));
    qmap::obs::trace_to(&path).expect("attach trace file");
    let traced = run_front(&Engine::new(2));
    qmap::obs::trace_close();
    assert_eq!(
        untraced, traced,
        "an attached trace must never change the front"
    );
    // and both match the single-threaded serial model
    let serial = run_front(&Engine::new(1));
    assert_eq!(serial, traced, "traced run diverged from the serial model");

    // the trace is schema-versioned JSONL: header first, every line
    // parses, and the engine's instrumented layers all show up
    let src = std::fs::read_to_string(&path).expect("trace file readable");
    let mut kinds = std::collections::BTreeSet::new();
    for (i, line) in src.lines().enumerate() {
        let v = parse(line).unwrap_or_else(|e| panic!("trace line {}: {e}", i + 1));
        let kind = v.get("event").as_str().expect("event kind").to_string();
        if i == 0 {
            assert_eq!(kind, "trace_start", "header must lead the trace");
            assert_eq!(
                v.get("schema").as_f64(),
                Some(qmap::obs::SCHEMA_VERSION as f64)
            );
        }
        assert!(v.get("seq").as_f64().is_some(), "line {}: no seq", i + 1);
        assert!(v.get("t_us").as_f64().is_some(), "line {}: no t_us", i + 1);
        kinds.insert(kind);
    }
    for want in ["trace_start", "job", "shard", "gen_eval"] {
        assert!(
            kinds.contains(want),
            "trace must record {want} events (saw {kinds:?})"
        );
    }
    // the report command digests it without error
    let summary = qmap::obs::report::report(&src).expect("trace-report");
    assert!(summary.contains("schema 1"), "{summary}");
    let _ = std::fs::remove_file(&path);
}

/// `trace_close` is idempotent and detaches cleanly: events recorded
/// after close must not land in the file.
#[test]
fn closing_the_trace_detaches_the_file() {
    let _guard = TRACE_LOCK.lock().unwrap();
    let mut p = std::env::temp_dir();
    p.push(format!("qmap_obs_close_{}.jsonl", std::process::id()));
    let path = p.to_string_lossy().into_owned();
    qmap::obs::trace_to(&path).expect("attach");
    qmap::obs::event("obs_close_probe_in", vec![]);
    qmap::obs::trace_close();
    qmap::obs::trace_close(); // idempotent
    qmap::obs::event("obs_close_probe_out", vec![]);
    let src = std::fs::read_to_string(&path).expect("readable");
    assert!(src.contains("obs_close_probe_in"));
    assert!(!src.contains("obs_close_probe_out"));
    let _ = std::fs::remove_file(&path);
}
