//! Workload-result cache (the paper's §III-A caching mechanism).
//!
//! "Once a layer workload has been evaluated, the results are stored in
//! a cache. Subsequently, the cached results can be read and reused when
//! trying to find the best plan for the same workload." NSGA-II genomes
//! share most of their layers, so hit rates are high after the first
//! generation.
//!
//! The cache is keyed by `workload_hash(layer, quant)` (shape + strides
//! + kind + bit-widths) and the architecture name, is thread-safe, and
//! can persist to a JSON file across runs.

use super::{search, workload_hash, MapperConfig};
use crate::arch::Arch;
use crate::quant::LayerQuant;
use crate::util::json::{parse, Json};
use crate::workload::ConvLayer;
use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// The cached summary of one workload evaluation (everything the search
/// engine needs; the winning mapping itself is not persisted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CachedEval {
    pub energy_pj: f64,
    pub memory_energy_pj: f64,
    pub cycles: f64,
    pub edp: f64,
    pub valid_mappings: u64,
    /// Per-level memory energy is folded to the three coarse components
    /// reported in Fig. 4: innermost (spads/regs), middle (GLB/PE bufs),
    /// DRAM.
    pub energy_breakdown_pj: [f64; 3],
    pub mac_energy_pj: f64,
}

/// Thread-safe mapper cache.
pub struct MapperCache {
    map: RwLock<FxHashMap<u64, CachedEval>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for MapperCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MapperCache {
    pub fn new() -> Self {
        MapperCache {
            map: RwLock::new(FxHashMap::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn key(arch: &Arch, layer: &ConvLayer, q: &LayerQuant) -> u64 {
        // packing-equivalent settings share one entry (see mapper::search)
        let q = &q.canonical(arch.word_bits, arch.bit_packing);
        let mut h = workload_hash(layer, q);
        for b in arch.name.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= (arch.bit_packing as u64) << 7;
        h
    }

    /// Evaluate a workload through the cache, running the mapper on miss.
    pub fn evaluate(
        &self,
        arch: &Arch,
        layer: &ConvLayer,
        q: &LayerQuant,
        cfg: &MapperConfig,
    ) -> Option<CachedEval> {
        let key = Self::key(arch, layer, q);
        if let Some(hit) = self.map.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(*hit);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let r = search(arch, layer, q, cfg);
        let est = r.best?;
        let nl = est.level_energy_pj.len();
        let mut breakdown = [0.0f64; 3];
        for (i, &e) in est.level_energy_pj.iter().enumerate() {
            let slot = if i == nl - 1 {
                2 // DRAM
            } else if i == 0 {
                0 // innermost spads/regs
            } else {
                1 // middle buffers
            };
            breakdown[slot] += e;
        }
        let cached = CachedEval {
            energy_pj: est.energy_pj,
            memory_energy_pj: est.memory_energy_pj(),
            cycles: est.cycles,
            edp: est.edp(),
            valid_mappings: r.valid,
            energy_breakdown_pj: breakdown,
            mac_energy_pj: est.mac_energy_pj,
        };
        self.map.write().unwrap().insert(key, cached);
        Some(cached)
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialize to JSON (for cross-run persistence).
    pub fn to_json(&self) -> String {
        let map = self.map.read().unwrap();
        let mut entries = Vec::with_capacity(map.len());
        for (k, v) in map.iter() {
            entries.push(Json::obj(vec![
                ("key", Json::Str(format!("{k:016x}"))),
                ("energy_pj", Json::Num(v.energy_pj)),
                ("memory_energy_pj", Json::Num(v.memory_energy_pj)),
                ("cycles", Json::Num(v.cycles)),
                ("edp", Json::Num(v.edp)),
                ("valid_mappings", Json::Num(v.valid_mappings as f64)),
                ("breakdown", Json::arr_f64(&v.energy_breakdown_pj)),
                ("mac_energy_pj", Json::Num(v.mac_energy_pj)),
            ]));
        }
        Json::obj(vec![("entries", Json::Arr(entries))]).to_string()
    }

    /// Load entries from a JSON dump produced by `to_json`.
    pub fn load_json(&self, src: &str) -> Result<usize, String> {
        let v = parse(src)?;
        let entries = v.get("entries").as_arr().ok_or("missing entries")?;
        let mut map = self.map.write().unwrap();
        let mut n = 0;
        for e in entries {
            let key = u64::from_str_radix(e.get("key").as_str().ok_or("key")?, 16)
                .map_err(|_| "bad key")?;
            let bd = e.get("breakdown").as_arr().ok_or("breakdown")?;
            if bd.len() != 3 {
                return Err("breakdown len".into());
            }
            map.insert(
                key,
                CachedEval {
                    energy_pj: e.get("energy_pj").as_f64().ok_or("energy")?,
                    memory_energy_pj: e.get("memory_energy_pj").as_f64().ok_or("mem")?,
                    cycles: e.get("cycles").as_f64().ok_or("cycles")?,
                    edp: e.get("edp").as_f64().ok_or("edp")?,
                    valid_mappings: e.get("valid_mappings").as_f64().ok_or("valid")? as u64,
                    energy_breakdown_pj: [
                        bd[0].as_f64().ok_or("bd0")?,
                        bd[1].as_f64().ok_or("bd1")?,
                        bd[2].as_f64().ok_or("bd2")?,
                    ],
                    mac_energy_pj: e.get("mac_energy_pj").as_f64().ok_or("mac")?,
                },
            );
            n += 1;
        }
        Ok(n)
    }

    /// Persist to a file (best-effort convenience).
    pub fn save_file(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Load from a file if it exists; returns entries loaded.
    pub fn load_file(&self, path: &str) -> usize {
        match std::fs::read_to_string(path) {
            Ok(src) => self.load_json(&src).unwrap_or(0),
            Err(_) => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::toy;

    fn cfg() -> MapperConfig {
        MapperConfig {
            valid_target: 100,
            max_draws: 50_000,
            seed: 1,
        }
    }

    #[test]
    fn second_lookup_hits() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8);
        let r1 = cache.evaluate(&a, &l, &q, &cfg()).unwrap();
        assert_eq!(cache.misses(), 1);
        let r2 = cache.evaluate(&a, &l, &q, &cfg()).unwrap();
        assert_eq!(cache.hits(), 1);
        assert_eq!(r1, r2);
    }

    #[test]
    fn different_quant_misses() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        cache.evaluate(&a, &l, &LayerQuant::uniform(8), &cfg()).unwrap();
        cache.evaluate(&a, &l, &LayerQuant::uniform(4), &cfg()).unwrap();
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(8);
        let r1 = cache.evaluate(&a, &l, &q, &cfg()).unwrap();

        let dump = cache.to_json();
        let cache2 = MapperCache::new();
        assert_eq!(cache2.load_json(&dump).unwrap(), 1);
        // the restored entry is served as a hit
        let r2 = cache2.evaluate(&a, &l, &q, &cfg()).unwrap();
        assert_eq!(cache2.hits(), 1);
        assert_eq!(r1, r2);
    }

    #[test]
    fn breakdown_sums_to_memory_energy() {
        let cache = MapperCache::new();
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let r = cache
            .evaluate(&a, &l, &LayerQuant::uniform(8), &cfg())
            .unwrap();
        let s: f64 = r.energy_breakdown_pj.iter().sum();
        assert!((s - r.memory_energy_pj).abs() < 1e-6);
    }

    #[test]
    fn corrupt_json_rejected() {
        let cache = MapperCache::new();
        assert!(cache.load_json("{\"entries\": [{\"key\": \"zz\"}]}").is_err());
        assert!(cache.load_json("not json").is_err());
    }
}
