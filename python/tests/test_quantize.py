"""Properties of the fake-quantization primitive."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.quantize import fake_quant, qparams, quant_dequant

jax.config.update("jax_platform_name", "cpu")


def _rand(seed, shape, lo=-4.0, hi=4.0):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32, lo, hi)


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 8), seed=st.integers(0, 1000))
def test_levels_bound(bits, seed):
    """quant_dequant output takes at most 2^bits distinct values."""
    t = _rand(seed, (64,))
    dq = np.asarray(quant_dequant(t, jnp.float32(bits)))
    distinct = len(np.unique(np.round(dq, 5)))
    assert distinct <= 2**bits


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 12), seed=st.integers(0, 1000))
def test_error_bounded_by_half_step(bits, seed):
    t = _rand(seed, (128,))
    tmin, scale = qparams(t, jnp.float32(bits))
    dq = quant_dequant(t, jnp.float32(bits))
    err = np.abs(np.asarray(dq - t))
    assert err.max() <= float(scale) / 2 + 1e-6
    assert float(tmin) <= float(t.min()) + 1e-6


def test_range_endpoints_exact():
    """min and max of the tensor are representable exactly."""
    t = jnp.array([-1.5, 0.0, 2.5], jnp.float32)
    dq = np.asarray(quant_dequant(t, jnp.float32(2)))
    assert dq[0] == -1.5
    assert dq[2] == 2.5


def test_monotone_in_bits():
    t = _rand(42, (256,))
    errs = []
    for b in range(2, 9):
        dq = quant_dequant(t, jnp.float32(b))
        errs.append(float(jnp.mean((dq - t) ** 2)))
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-9, errs


def test_ste_gradient_is_identity():
    t = _rand(1, (32,))

    def f(t):
        return jnp.sum(fake_quant(t, jnp.float32(3)) * 2.0)

    g = np.asarray(jax.grad(f)(t))
    np.testing.assert_allclose(g, 2.0 * np.ones(32), rtol=1e-6)


def test_constant_tensor_stable():
    t = jnp.full((16,), 3.25, jnp.float32)
    dq = np.asarray(quant_dequant(t, jnp.float32(4)))
    np.testing.assert_allclose(dq, 3.25, atol=1e-5)


def test_idempotent():
    """Quantizing an already-quantized tensor is (near) identity."""
    t = _rand(7, (64,))
    once = quant_dequant(t, jnp.float32(4))
    twice = quant_dequant(once, jnp.float32(4))
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), atol=1e-5)
