//! Fig. 6: accuracy vs EDP trade-off on Eyeriss running MobileNetV1,
//! all axes relative to the uniform 8-bit implementation. Four arms:
//!
//!   * Proposed       — NSGA-II against Eyeriss (hardware-aware),
//!   * Uniform        — uniform 2..8-bit sweep,
//!   * Naïve          — NSGA-II against model size only (HW-unaware),
//!   * Proposed-Simba — NSGA-II against Simba, re-priced on Eyeriss
//!                      (the paper's "unseen accelerator" arm).
//!
//! Paper shape to reproduce: Proposed dominates Naïve and Uniform;
//! optimizing for the wrong accelerator is measurably worse than native.
//!
//! Run: `cargo bench --bench fig6_tradeoff`.

use qmap::coordinator::experiments::fig6_tradeoff;
use qmap::coordinator::RunConfig;
use qmap::report;
use std::time::Instant;

fn main() {
    let rc = RunConfig::from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2)
    });
    println!("=== Fig. 6: strategy comparison (MobileNetV1, Eyeriss, rel. uniform-8) ===");
    let t0 = Instant::now();
    let r = fig6_tradeoff(&rc);
    let dt = t0.elapsed();
    let (ref_edp, _ref_mem, ref_acc) = r.reference;

    let arms = [
        ("Proposed", 'P', &r.proposed),
        ("Uniform", 'u', &r.uniform),
        ("Naive", 'n', &r.naive),
        ("Proposed-for-Simba", 's', &r.cross),
    ];
    let mut pts = Vec::new();
    for (label, m, cands) in &arms {
        println!("{label}: {} candidates", cands.len());
        pts.extend(
            cands
                .iter()
                .map(|c| (c.hw.edp / ref_edp, c.accuracy - ref_acc, *m)),
        );
    }
    println!("\nP=proposed u=uniform n=naive s=proposed-for-simba:");
    print!(
        "{}",
        report::ascii_scatter(&pts, 76, 22, "EDP rel. uniform-8", "Δ top-1 vs uniform-8")
    );

    println!("\n{}", report::pareto_table(&r.proposed, r.reference.0, r.reference.1, r.reference.2));

    // dominance checks: for each baseline point, does some proposed
    // point have <= EDP and >= accuracy (strictly better in one)?
    let dominated_frac = |cands: &[qmap::baselines::Candidate]| {
        if cands.is_empty() {
            return 0.0;
        }
        let d = cands
            .iter()
            .filter(|b| {
                r.proposed.iter().any(|p| {
                    p.hw.edp <= b.hw.edp
                        && p.accuracy >= b.accuracy
                        && (p.hw.edp < b.hw.edp || p.accuracy > b.accuracy)
                })
            })
            .count();
        d as f64 / cands.len() as f64
    };
    let du = dominated_frac(&r.uniform);
    let dn = dominated_frac(&r.naive);
    let dc = dominated_frac(&r.cross);
    println!("proposed dominates {:.0}% of uniform points", du * 100.0);
    println!("proposed dominates {:.0}% of naive points", dn * 100.0);
    println!("proposed dominates {:.0}% of cross-accelerator points", dc * 100.0);

    // headline: best EDP saving with "no accuracy drop" — the paper's
    // Table II cells sit within +-0.5% of the reference, so we accept
    // candidates within 0.2% (proxy evaluation noise included)
    let best_saving = r
        .proposed
        .iter()
        .filter(|c| c.accuracy >= ref_acc - 0.002)
        .map(|c| 1.0 - c.hw.edp / ref_edp)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nheadline: best EDP saving at no accuracy drop = {:.1}% (paper: energy savings up to 37%)",
        best_saving * 100.0
    );
    println!(
        "paper shape: {}",
        if du >= 0.5 && dn >= 0.3 && best_saving > 0.10 {
            "REPRODUCED"
        } else {
            "MISMATCH"
        }
    );

    let mut rows = Vec::new();
    for (label, _, cands) in &arms {
        for c in cands.iter() {
            rows.push(vec![
                label.to_string(),
                format!("{:.6}", c.accuracy),
                format!("{:.6e}", c.hw.edp),
                format!("{:.6e}", c.hw.memory_energy_pj),
                format!("{:.6}", c.hw.edp / ref_edp),
                format!("{:.6}", c.accuracy - ref_acc),
            ]);
        }
    }
    let path = report::write_results(
        "fig6_tradeoff.csv",
        &report::csv(
            &["strategy", "accuracy", "edp", "mem_energy_pj", "edp_rel_u8", "dacc_vs_u8"],
            &rows,
        ),
    );
    let mut plot = report::svg::Plot::new(
        "Fig 6: accuracy vs EDP (rel. uniform-8), MobileNetV1 on Eyeriss",
        "EDP rel. uniform-8",
        "delta top-1 vs uniform-8",
    );
    for (label, _, cands) in &arms {
        let pts: Vec<(f64, f64)> = cands
            .iter()
            .map(|c| (c.hw.edp / ref_edp, c.accuracy - ref_acc))
            .collect();
        plot.scatter(label, &pts);
    }
    report::write_results("fig6.svg", &plot.render());
    println!("[{dt:.2?}] wrote {} (+ fig6.svg)", path.display());
}
