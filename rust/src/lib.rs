//! # qmap — Quantization x Mapping synergy for DNN accelerators
//!
//! A from-scratch reproduction of *"Exploring Quantization and Mapping
//! Synergy in Hardware-Aware Deep Neural Network Accelerators"*
//! (Klhufek et al., DDECS 2024): a Timeloop-style analytical mapping
//! engine extended with mixed-precision quantization and bit-packing, a
//! QAT training engine (JAX/Pallas, AOT-compiled, executed from Rust via
//! PJRT), and an NSGA-II search engine coupling the two.
//!
//! Layering (DESIGN.md §4):
//! * L3 (this crate): mapping engine, NSGA-II, caching, CLI, runtime.
//! * L2 (`python/compile/model.py`): JAX QAT model, AOT-lowered to HLO.
//! * L1 (`python/compile/kernels/`): Pallas fake-quant matmul kernel.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod accuracy;
pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod engine;
pub mod eval;
pub mod mapper;
pub mod mapping;
pub mod model;
pub mod nest;
pub mod nsga;
pub mod objective;
pub mod obs;
pub mod quant;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod util;
pub mod workload;
