//! Minimal SVG figure writer (no plotting crates offline): scatter
//! plots, poly-lines, and stacked bar charts — enough to regenerate the
//! paper's figures as real graphics next to the ASCII renderings.
//!
//! The API is builder-ish: create a [`Plot`], add series, render to an
//! SVG string, then persist via [`crate::report::write_results`].

use std::fmt::Write as _;

/// One data series in a plot.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
    /// CSS color.
    pub color: String,
    /// Draw a connecting poly-line (in x-sorted order) as well as dots.
    pub line: bool,
}

/// A 2-D scatter/line figure.
#[derive(Debug, Clone)]
pub struct Plot {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
    pub width: u32,
    pub height: u32,
    pub log_x: bool,
}

const PALETTE: [&str; 8] = [
    "#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd", "#8c564b", "#e377c2", "#17becf",
];

const MARGIN_L: f64 = 64.0;
const MARGIN_R: f64 = 16.0;
const MARGIN_T: f64 = 34.0;
const MARGIN_B: f64 = 46.0;

impl Plot {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Self {
        Plot {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
            width: 640,
            height: 420,
            log_x: false,
        }
    }

    /// Add a series with an automatic palette color.
    pub fn scatter(&mut self, label: &str, points: &[(f64, f64)]) -> &mut Self {
        self.push(label, points, false)
    }

    pub fn line(&mut self, label: &str, points: &[(f64, f64)]) -> &mut Self {
        self.push(label, points, true)
    }

    fn push(&mut self, label: &str, points: &[(f64, f64)], line: bool) -> &mut Self {
        let color = PALETTE[self.series.len() % PALETTE.len()].to_string();
        self.series.push(Series {
            label: label.into(),
            points: points.to_vec(),
            color,
            line,
        });
        self
    }

    fn bounds(&self) -> (f64, f64, f64, f64) {
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for s in &self.series {
            for &(x, y) in &s.points {
                if !x.is_finite() || !y.is_finite() {
                    continue;
                }
                x0 = x0.min(x);
                x1 = x1.max(x);
                y0 = y0.min(y);
                y1 = y1.max(y);
            }
        }
        if !x0.is_finite() {
            return (0.0, 1.0, 0.0, 1.0);
        }
        if x1 <= x0 {
            x1 = x0 + 1.0;
        }
        if y1 <= y0 {
            y1 = y0 + 1.0;
        }
        // 4% padding
        let (dx, dy) = (0.04 * (x1 - x0), 0.04 * (y1 - y0));
        (x0 - dx, x1 + dx, y0 - dy, y1 + dy)
    }

    /// Render to an SVG document string.
    pub fn render(&self) -> String {
        let (w, h) = (self.width as f64, self.height as f64);
        let (x0, x1, y0, y1) = self.bounds();
        let (tx, ty) = |log_x: bool| -> (Box<dyn Fn(f64) -> f64>, Box<dyn Fn(f64) -> f64>) {
            let (lx0, lx1) = if log_x {
                (x0.max(1e-300).ln(), x1.max(1e-299).ln())
            } else {
                (x0, x1)
            };
            let span_x = lx1 - lx0;
            let tx = move |x: f64| {
                let v = if log_x { x.max(1e-300).ln() } else { x };
                MARGIN_L + (v - lx0) / span_x * (w - MARGIN_L - MARGIN_R)
            };
            let span_y = y1 - y0;
            let ty = move |y: f64| h - MARGIN_B - (y - y0) / span_y * (h - MARGIN_T - MARGIN_B);
            (Box::new(tx), Box::new(ty))
        }(self.log_x);

        let mut out = String::new();
        let _ = write!(
            out,
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{}" height="{}" font-family="sans-serif" font-size="11">"#,
            self.width, self.height
        );
        let _ = write!(
            out,
            r#"<rect width="100%" height="100%" fill="white"/><text x="{}" y="18" text-anchor="middle" font-size="13" font-weight="bold">{}</text>"#,
            w / 2.0,
            esc(&self.title)
        );

        // axes
        let _ = write!(
            out,
            r#"<line x1="{MARGIN_L}" y1="{}" x2="{}" y2="{}" stroke="black"/><line x1="{MARGIN_L}" y1="{MARGIN_T}" x2="{MARGIN_L}" y2="{}" stroke="black"/>"#,
            h - MARGIN_B,
            w - MARGIN_R,
            h - MARGIN_B,
            h - MARGIN_B
        );
        // tick labels (min/mid/max)
        for (frac, xv) in [(0.0, x0), (0.5, (x0 + x1) / 2.0), (1.0, x1)] {
            let px = MARGIN_L + frac * (w - MARGIN_L - MARGIN_R);
            let _ = write!(
                out,
                r#"<text x="{px}" y="{}" text-anchor="middle">{}</text>"#,
                h - MARGIN_B + 16.0,
                fmt_tick(if self.log_x {
                    (x0.max(1e-300).ln() + frac * (x1.max(1e-299).ln() - x0.max(1e-300).ln())).exp()
                } else {
                    xv
                })
            );
        }
        for (frac, yv) in [(0.0, y0), (0.5, (y0 + y1) / 2.0), (1.0, y1)] {
            let py = h - MARGIN_B - frac * (h - MARGIN_T - MARGIN_B);
            let _ = write!(
                out,
                r#"<text x="{}" y="{}" text-anchor="end">{}</text>"#,
                MARGIN_L - 6.0,
                py + 4.0,
                fmt_tick(yv)
            );
        }
        // axis labels
        let _ = write!(
            out,
            r#"<text x="{}" y="{}" text-anchor="middle">{}</text>"#,
            (MARGIN_L + w - MARGIN_R) / 2.0,
            h - 8.0,
            esc(&self.x_label)
        );
        let _ = write!(
            out,
            r#"<text x="14" y="{}" text-anchor="middle" transform="rotate(-90 14 {})">{}</text>"#,
            (MARGIN_T + h - MARGIN_B) / 2.0,
            (MARGIN_T + h - MARGIN_B) / 2.0,
            esc(&self.y_label)
        );

        // series
        for s in &self.series {
            if s.line {
                let mut pts: Vec<(f64, f64)> = s.points.clone();
                pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                let path: Vec<String> = pts
                    .iter()
                    .map(|&(x, y)| format!("{:.1},{:.1}", tx(x), ty(y)))
                    .collect();
                let _ = write!(
                    out,
                    r#"<polyline points="{}" fill="none" stroke="{}" stroke-width="1.5"/>"#,
                    path.join(" "),
                    s.color
                );
            }
            for &(x, y) in &s.points {
                if !x.is_finite() || !y.is_finite() {
                    continue;
                }
                let _ = write!(
                    out,
                    r#"<circle cx="{:.1}" cy="{:.1}" r="2.6" fill="{}" fill-opacity="0.75"/>"#,
                    tx(x),
                    ty(y),
                    s.color
                );
            }
        }

        // legend
        for (i, s) in self.series.iter().enumerate() {
            let ly = MARGIN_T + 6.0 + i as f64 * 15.0;
            let _ = write!(
                out,
                r#"<rect x="{}" y="{}" width="10" height="10" fill="{}"/><text x="{}" y="{}">{}</text>"#,
                w - MARGIN_R - 150.0,
                ly,
                s.color,
                w - MARGIN_R - 136.0,
                ly + 9.0,
                esc(&s.label)
            );
        }
        out.push_str("</svg>");
        out
    }
}

/// Stacked bar chart (Fig. 4-style energy breakdowns).
pub fn stacked_bars(
    title: &str,
    categories: &[String],
    component_labels: &[&str],
    values: &[Vec<f64>], // values[bar][component]
) -> String {
    let (w, h) = (640.0f64, 420.0f64);
    let max_total: f64 = values
        .iter()
        .map(|v| v.iter().sum::<f64>())
        .fold(0.0, f64::max)
        .max(1e-300);
    let n = categories.len().max(1) as f64;
    let band = (w - MARGIN_L - MARGIN_R) / n;
    let bar_w = band * 0.6;

    let mut out = String::new();
    let _ = write!(
        out,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" font-family="sans-serif" font-size="11"><rect width="100%" height="100%" fill="white"/><text x="{}" y="18" text-anchor="middle" font-size="13" font-weight="bold">{}</text>"#,
        w / 2.0,
        esc(title)
    );
    let _ = write!(
        out,
        r#"<line x1="{MARGIN_L}" y1="{}" x2="{}" y2="{}" stroke="black"/>"#,
        h - MARGIN_B,
        w - MARGIN_R,
        h - MARGIN_B
    );
    for (bi, (cat, vals)) in categories.iter().zip(values).enumerate() {
        let x = MARGIN_L + bi as f64 * band + (band - bar_w) / 2.0;
        let mut y = h - MARGIN_B;
        for (ci, &v) in vals.iter().enumerate() {
            let bh = v / max_total * (h - MARGIN_T - MARGIN_B);
            y -= bh;
            let _ = write!(
                out,
                r#"<rect x="{x:.1}" y="{y:.1}" width="{bar_w:.1}" height="{bh:.1}" fill="{}"/>"#,
                PALETTE[ci % PALETTE.len()]
            );
        }
        let _ = write!(
            out,
            r#"<text x="{:.1}" y="{}" text-anchor="middle">{}</text>"#,
            x + bar_w / 2.0,
            h - MARGIN_B + 16.0,
            esc(cat)
        );
    }
    for (ci, label) in component_labels.iter().enumerate() {
        let ly = MARGIN_T + 6.0 + ci as f64 * 15.0;
        let _ = write!(
            out,
            r#"<rect x="{}" y="{ly}" width="10" height="10" fill="{}"/><text x="{}" y="{}">{}</text>"#,
            w - MARGIN_R - 120.0,
            PALETTE[ci % PALETTE.len()],
            w - MARGIN_R - 106.0,
            ly + 9.0,
            esc(label)
        );
    }
    out.push_str("</svg>");
    out
}

/// All 2-D projections of a k-dimensional objective front: one scatter
/// per axis pair `(i, j)` with `i < j`, in spec order. `axes` names the
/// axes (the objective spec's canonical names) and each point carries
/// one value per axis; non-finite coordinates (unmappable genomes'
/// `+inf` hardware axes) are dropped per-plot by the renderer. Returns
/// `(file_stem, svg)` pairs, e.g. `("front_error_vs_energy", ...)` —
/// `k*(k-1)/2` plots, which for the paper's 2-objective default is the
/// single figure the reports always drew.
pub fn front_projections(title: &str, axes: &[&str], points: &[Vec<f64>]) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for i in 0..axes.len() {
        for j in i + 1..axes.len() {
            let pts: Vec<(f64, f64)> = points
                .iter()
                .filter(|p| p.len() == axes.len())
                .map(|p| (p[i], p[j]))
                .collect();
            let mut plot = Plot::new(
                &format!("{title}: {} vs {}", axes[i], axes[j]),
                axes[i],
                axes[j],
            );
            plot.scatter("front", &pts);
            out.push((format!("front_{}_vs_{}", axes[i], axes[j]), plot.render()));
        }
    }
    out
}

fn fmt_tick(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    let a = v.abs();
    if a >= 1e4 || a < 1e-2 {
        format!("{v:.1e}")
    } else if a >= 10.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

fn esc(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_renders_valid_svg() {
        let mut p = Plot::new("t", "x", "y");
        p.scatter("a", &[(0.0, 0.0), (1.0, 2.0)]);
        p.line("b", &[(0.0, 1.0), (1.0, 0.5), (0.5, 0.7)]);
        let svg = p.render();
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<circle").count(), 5);
        assert_eq!(svg.matches("<polyline").count(), 1);
        assert!(svg.contains(">t<"));
    }

    #[test]
    fn empty_plot_does_not_panic() {
        let p = Plot::new("empty", "x", "y");
        let svg = p.render();
        assert!(svg.contains("</svg>"));
    }

    #[test]
    fn degenerate_single_point() {
        let mut p = Plot::new("one", "x", "y");
        p.scatter("s", &[(3.0, 3.0)]);
        let svg = p.render();
        assert!(svg.contains("<circle"));
        // no NaN coordinates leaked
        assert!(!svg.contains("NaN"));
    }

    #[test]
    fn log_x_handles_wide_ranges() {
        let mut p = Plot::new("log", "x", "y");
        p.log_x = true;
        p.scatter("s", &[(1.0, 0.0), (1e9, 1.0)]);
        let svg = p.render();
        assert!(!svg.contains("NaN") && !svg.contains("inf"));
    }

    #[test]
    fn stacked_bars_render() {
        let svg = stacked_bars(
            "breakdown",
            &["16b".into(), "8b".into()],
            &["mem", "mac"],
            &[vec![2.0, 1.0], vec![1.0, 1.0]],
        );
        assert_eq!(svg.matches("<rect").count(), 1 + 4 + 2); // bg + bars + legend
        assert!(svg.contains("</svg>"));
    }

    #[test]
    fn three_objective_front_yields_three_projections() {
        let axes = ["error", "energy", "weight_words"];
        let pts = vec![
            vec![0.1, 5.0, 100.0],
            vec![0.2, 4.0, 90.0],
            vec![0.3, f64::INFINITY, 80.0], // unmappable: dropped where non-finite
        ];
        let figs = front_projections("3-obj front", &axes, &pts);
        assert_eq!(figs.len(), 3); // C(3,2)
        let names: Vec<&str> = figs.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "front_error_vs_energy",
                "front_error_vs_weight_words",
                "front_energy_vs_weight_words"
            ]
        );
        for (_, svg) in &figs {
            assert!(svg.starts_with("<svg") && svg.ends_with("</svg>"));
            assert!(!svg.contains("NaN") && !svg.contains("inf"));
        }
        // the 2-objective default degenerates to the single usual plot
        assert_eq!(front_projections("t", &["edp", "error"], &[]).len(), 1);
    }

    #[test]
    fn xml_escaping() {
        let mut p = Plot::new("a<b & c", "x", "y");
        p.scatter("s<1>", &[(0.0, 0.0)]);
        let svg = p.render();
        assert!(svg.contains("a&lt;b &amp; c"));
        assert!(!svg.contains("s<1>"));
    }
}
