//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   1. mapper algorithm — Timeloop-style random search (the paper's
//!      configuration, 2000 valid mappings) vs a GAMMA-style genetic
//!      mapper at the same evaluation budget (paper ref. [8]);
//!   2. bit-packing on/off — what the paper's Timeloop extension is
//!      worth, end-to-end on MobileNetV1;
//!   3. mapper budget — best-EDP quality vs number of valid mappings
//!      (500 .. 8000), quantifying the paper's 2000-mapping choice.
//!
//! Run: `cargo bench --bench ablation_mapper`.

use qmap::arch::presets;
use qmap::eval::evaluate_network;
use qmap::mapper::cache::MapperCache;
use qmap::mapper::gamma::{self, GammaConfig};
use qmap::mapper::{self, MapperConfig};
use qmap::quant::{LayerQuant, QuantConfig};
use qmap::report;
use qmap::workload::models;
use std::time::Instant;

fn main() {
    let arch = presets::eyeriss();
    let layers = models::mobilenet_v1();

    // ---------------------------------------------- 1. random vs GAMMA
    println!("=== ablation 1: random mapper vs GAMMA-style genetic mapper ===");
    let gcfg = GammaConfig {
        population: 40,
        generations: 49,
        ..GammaConfig::default()
    };
    let budget = gcfg.budget(); // == 2000 evaluations
    let rcfg = MapperConfig {
        valid_target: budget,
        max_draws: budget * 200,
        seed: 3,
        shards: 1,
    };
    let probe = [1usize, 3, 8, 13, 22, 27]; // dw, pw, early/late layers
    let mut rows = Vec::new();
    let (mut t_rnd, mut t_gam) = (0.0f64, 0.0f64);
    let mut gam_wins = 0usize;
    for &i in &probe {
        let l = &layers[i];
        let q = LayerQuant { qa: 8, qw: 8, qo: 8 };
        let t0 = Instant::now();
        let r = mapper::search(&arch, l, &q, &rcfg);
        t_rnd += t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let g = gamma::search(&arch, l, &q, &gcfg);
        t_gam += t1.elapsed().as_secs_f64();
        let er = r.best.map(|e| e.edp()).unwrap_or(f64::INFINITY);
        let eg = g.best.map(|e| e.edp()).unwrap_or(f64::INFINITY);
        if eg <= er {
            gam_wins += 1;
        }
        rows.push(vec![
            l.name.clone(),
            format!("{:.4e}", er),
            format!("{:.4e}", eg),
            format!("{:+.1}%", (eg / er - 1.0) * 100.0),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["layer", "random-2000 best EDP", "GAMMA-2000 best EDP", "GAMMA vs random"],
            &rows
        )
    );
    println!(
        "GAMMA wins or ties {gam_wins}/{} layers at equal budget ({}); random {:.2}s, gamma {:.2}s\n",
        probe.len(),
        budget,
        t_rnd,
        t_gam
    );

    // ---------------------------------------------- 2. bit-packing off
    println!("=== ablation 2: the paper's bit-packing extension on/off (MobileNetV1, 4-bit) ===");
    let mut no_pack = arch.clone();
    no_pack.bit_packing = false;
    no_pack.name = "eyeriss-nopack".into();
    let qc4 = QuantConfig::uniform(layers.len(), 4);
    let qc8 = QuantConfig::uniform(layers.len(), 8);
    let cfg = MapperConfig::default();
    let cache_p = MapperCache::new();
    let cache_n = MapperCache::new();
    let mut rows = Vec::new();
    for (label, qc) in [("8-bit", &qc8), ("4-bit", &qc4)] {
        let with = evaluate_network(&arch, &layers, qc, &cache_p, &cfg).unwrap();
        let without = evaluate_network(&no_pack, &layers, qc, &cache_n, &cfg).unwrap();
        // unpacked word count: one (or more) words per element
        let words_nopack: u64 = layers
            .iter()
            .zip(&qc.layers)
            .map(|(l, &(_, qw))| {
                qmap::quant::unpacked_words(
                    l.tensor_elements(qmap::workload::Tensor::Weights),
                    no_pack.word_bits,
                    qw,
                )
            })
            .sum();
        rows.push(vec![
            label.to_string(),
            format!("{:.4e}", with.memory_energy_pj),
            format!("{:.4e}", without.memory_energy_pj),
            format!("{:.2}x", without.memory_energy_pj / with.memory_energy_pj),
            format!("{}", with.weight_words),
            format!("{words_nopack}"),
        ]);
    }
    print!(
        "{}",
        report::table(
            &["setting", "mem energy (packed)", "mem energy (no pack)", "packing gain", "words (packed)", "words (no pack)"],
            &rows
        )
    );
    println!("without packing, sub-word quantization saves nothing — the paper's premise.\n");

    // ---------------------------------------------- 3. budget sweep
    println!("=== ablation 3: mapper budget (valid mappings) vs best network EDP ===");
    let mut rows = Vec::new();
    let mut last = f64::INFINITY;
    for target in [250u64, 500, 1000, 2000, 4000, 8000] {
        let cfg = MapperConfig {
            valid_target: target,
            max_draws: target * 500,
            seed: 5,
            shards: 1,
        };
        let cache = MapperCache::new();
        let t0 = Instant::now();
        let e = evaluate_network(&arch, &layers, &qc8, &cache, &cfg).unwrap();
        let dt = t0.elapsed();
        rows.push(vec![
            target.to_string(),
            format!("{:.4e}", e.edp),
            format!("{:+.2}%", (e.edp / last - 1.0) * 100.0),
            format!("{:.2?}", dt),
        ]);
        last = e.edp;
    }
    print!(
        "{}",
        report::table(&["valid mappings", "network EDP", "vs previous", "wall time"], &rows)
    );
    println!("diminishing returns past ~2000 valid mappings — the paper's budget is on the knee.");
}
