//! Full-size network layer tables used by the mapping-side experiments.
//!
//! These are the exact layer shapes of MobileNetV1 (224x224, width 1.0)
//! and MobileNetV2 (224x224, width 1.0) as evaluated in the paper. The
//! training-side experiments use a width-scaled variant (see
//! `scaled_mobilenet_v1`) that matches these tables layer-for-layer, so a
//! quantization genome indexes both consistently.

use super::ConvLayer;

/// MobileNetV1 @ 224x224, width multiplier 1.0: stem conv + 13 (dw, pw)
/// blocks + classifier FC = 28 quantizable layers (the paper's genome has
/// 56 integers = 28 layers x (q_a, q_w)).
pub fn mobilenet_v1() -> Vec<ConvLayer> {
    let mut layers = Vec::new();
    // stem: 3x3 conv, stride 2, 3 -> 32, output 112x112
    layers.push(ConvLayer::conv("conv1", 3, 32, 3, 112, 2));
    // (channels_in, channels_out, dw_stride, out_spatial_after_block)
    let blocks: [(u64, u64, u64, u64); 13] = [
        (32, 64, 1, 112),
        (64, 128, 2, 56),
        (128, 128, 1, 56),
        (128, 256, 2, 28),
        (256, 256, 1, 28),
        (256, 512, 2, 14),
        (512, 512, 1, 14),
        (512, 512, 1, 14),
        (512, 512, 1, 14),
        (512, 512, 1, 14),
        (512, 512, 1, 14),
        (512, 1024, 2, 7),
        (1024, 1024, 1, 7),
    ];
    for (i, &(cin, cout, stride, out)) in blocks.iter().enumerate() {
        layers.push(ConvLayer::dw(&format!("dw{}", i + 1), cin, 3, out, stride));
        layers.push(ConvLayer::pw(&format!("pw{}", i + 1), cin, cout, out));
    }
    // classifier (global-avg-pool then FC 1024 -> 1000)
    layers.push(ConvLayer::fc("fc", 1024, 1000));
    layers
}

/// MobileNetV2 @ 224x224, width 1.0: stem + 17 inverted-residual blocks
/// (expand pw, dw, project pw; the first block has no expand) + final 1x1
/// conv + FC = 53 quantizable layers.
pub fn mobilenet_v2() -> Vec<ConvLayer> {
    let mut layers = Vec::new();
    layers.push(ConvLayer::conv("conv1", 3, 32, 3, 112, 2));

    // (expansion t, out channels c, repeats n, first stride s) per stage
    let stages: [(u64, u64, u64, u64); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin: u64 = 32;
    let mut spatial: u64 = 112;
    let mut b = 0;
    for &(t, cout, n, s) in &stages {
        for rep in 0..n {
            b += 1;
            let stride = if rep == 0 { s } else { 1 };
            let hidden = cin * t;
            let out_sp = if stride == 2 { spatial / 2 } else { spatial };
            if t != 1 {
                layers.push(ConvLayer::pw(&format!("b{b}_expand"), cin, hidden, spatial));
            }
            layers.push(ConvLayer::dw(&format!("b{b}_dw"), hidden, 3, out_sp, stride));
            layers.push(ConvLayer::pw(&format!("b{b}_project"), hidden, cout, out_sp));
            cin = cout;
            spatial = out_sp;
        }
    }
    layers.push(ConvLayer::pw("conv_last", 320, 1280, 7));
    layers.push(ConvLayer::fc("fc", 1280, 1000));
    layers
}

/// The width-0.25, 32x32-input MobileNetV1 actually *trained* in this repo
/// (see DESIGN.md §3 substitutions). Layer-for-layer aligned with
/// `mobilenet_v1()` (28 layers), so bit-width genomes transfer 1:1. This
/// table must stay in sync with `python/compile/model.py::ARCH`.
pub fn scaled_mobilenet_v1(num_classes: u64) -> Vec<ConvLayer> {
    let w = |ch: u64| (ch / 4).max(8); // width multiplier 0.25, floor 8
    let mut layers = Vec::new();
    // stem stride 1 at 32x32 (stride-2 stem would shrink too aggressively)
    layers.push(ConvLayer::conv("conv1", 3, w(32), 3, 32, 1));
    let blocks: [(u64, u64, u64, u64); 13] = [
        (32, 64, 1, 32),
        (64, 128, 2, 16),
        (128, 128, 1, 16),
        (128, 256, 2, 8),
        (256, 256, 1, 8),
        (256, 512, 2, 4),
        (512, 512, 1, 4),
        (512, 512, 1, 4),
        (512, 512, 1, 4),
        (512, 512, 1, 4),
        (512, 512, 1, 4),
        (512, 1024, 2, 2),
        (1024, 1024, 1, 2),
    ];
    for (i, &(cin, cout, stride, out)) in blocks.iter().enumerate() {
        layers.push(ConvLayer::dw(&format!("dw{}", i + 1), w(cin), 3, out, stride));
        layers.push(ConvLayer::pw(&format!("pw{}", i + 1), w(cin), w(cout), out));
    }
    layers.push(ConvLayer::fc("fc", w(1024), num_classes));
    layers
}

/// Look up a model table by name.
pub fn by_name(name: &str) -> Option<Vec<ConvLayer>> {
    match name {
        "mobilenet_v1" | "v1" => Some(mobilenet_v1()),
        "mobilenet_v2" | "v2" => Some(mobilenet_v2()),
        "scaled_v1" => Some(scaled_mobilenet_v1(10)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{LayerKind, Tensor};

    #[test]
    fn v1_has_28_layers_and_56_genome_ints() {
        let m = mobilenet_v1();
        assert_eq!(m.len(), 28);
        assert_eq!(2 * m.len(), 56); // paper: "the string consists of 56 integers"
    }

    #[test]
    fn v1_macs_match_published() {
        // MobileNetV1 1.0 @224 is ~569M MACs (paper reports ~0.57 GMACs).
        let macs: u64 = mobilenet_v1().iter().map(|l| l.macs()).sum();
        assert!((550_000_000..600_000_000).contains(&macs), "macs={macs}");
    }

    #[test]
    fn v1_params_match_published() {
        // ~4.2M weight parameters.
        let params: u64 = mobilenet_v1()
            .iter()
            .map(|l| l.tensor_elements(Tensor::Weights))
            .sum();
        assert!((4_000_000..4_500_000).contains(&params), "params={params}");
    }

    #[test]
    fn v1_layer2_is_the_papers_depthwise() {
        // Table I uses "the second convolutional layer (a depthwise
        // convolutional layer)": 32ch 3x3 dw over 112x112.
        let m = mobilenet_v1();
        let l = &m[1];
        assert_eq!(l.kind, LayerKind::Depthwise);
        assert_eq!(l.size(crate::workload::Dim::K), 32);
        assert_eq!(l.size(crate::workload::Dim::P), 112);
    }

    #[test]
    fn v2_shape_sanity() {
        let m = mobilenet_v2();
        assert_eq!(m.len(), 53);
        // ~300M MACs and ~3.5M params for V2 1.0 @224 (conv+fc only).
        let macs: u64 = m.iter().map(|l| l.macs()).sum();
        assert!((290_000_000..330_000_000).contains(&macs), "macs={macs}");
        let params: u64 = m.iter().map(|l| l.tensor_elements(Tensor::Weights)).sum();
        assert!((3_200_000..3_700_000).contains(&params), "params={params}");
    }

    #[test]
    fn scaled_v1_aligns_with_full_v1() {
        let full = mobilenet_v1();
        let small = scaled_mobilenet_v1(10);
        assert_eq!(full.len(), small.len());
        for (f, s) in full.iter().zip(&small) {
            assert_eq!(f.kind, s.kind, "{}", f.name);
        }
        // small enough to fine-tune on CPU
        let params: u64 = small.iter().map(|l| l.tensor_elements(Tensor::Weights)).sum();
        assert!(params < 600_000, "params={params}");
    }

    #[test]
    fn spatial_dims_consistent_through_v2() {
        // every layer's input spatial size equals previous layer's output
        // size for stride-1 chains (smoke check of the stage wiring)
        let m = mobilenet_v2();
        for l in &m {
            let (h, _) = l.input_hw();
            assert!(h >= l.size(crate::workload::Dim::P));
        }
    }
}
