//! Equivalence and determinism properties of the allocation-free mapper
//! hot path.
//!
//! The refactored engine (`LayerContext` tables + `EvalContext` scratch
//! + `random_mapping_into`/`check`/`analyze_into`/`estimate_into`) must
//! be *bit-identical* to the naive path (`random_mapping`/`check`/
//! `analyze`/`estimate`) — same candidates, same verdicts, same floats.
//! The sharded search must be deterministic in (seed, shard-count), and
//! with one shard must reproduce the single-threaded reference loop
//! exactly.

use qmap::arch::presets::{eyeriss, simba, toy};
use qmap::arch::Arch;
use qmap::energy::{estimate, estimate_into, Estimate};
use qmap::mapper::{search, workload_hash, EvalContext, MapperConfig};
use qmap::mapping::mapspace::MapSpace;
use qmap::mapping::{check, LayerContext};
use qmap::nest::{analyze, analyze_into, NestAnalysis};
use qmap::quant::LayerQuant;
use qmap::util::rng::Rng;
use qmap::workload::ConvLayer;

fn layers_under_test() -> Vec<ConvLayer> {
    vec![
        ConvLayer::conv("c1", 4, 8, 3, 8, 1),
        ConvLayer::conv("c2", 16, 32, 3, 14, 2),
        ConvLayer::dw("d1", 32, 3, 14, 1),
        ConvLayer::pw("p1", 16, 32, 14),
        ConvLayer::fc("f1", 64, 10),
    ]
}

#[test]
fn ctx_analysis_is_bit_identical_to_naive_path() {
    let mut total_checked = 0usize;
    for arch in [toy(), eyeriss(), simba()] {
        let space = MapSpace::of(&arch);
        let mut ectx = EvalContext::for_arch(&arch);
        for layer in layers_under_test() {
            for bits in [2u8, 4, 8] {
                let q = LayerQuant::uniform(bits).canonical(arch.word_bits, arch.bit_packing);
                let lctx = LayerContext::new(&arch, &layer, &q);
                let mut rng = Rng::new(0xB17 ^ bits as u64);
                for _ in 0..150 {
                    let m = space.random_mapping(&layer, &mut rng);
                    let naive = check(&arch, &layer, &q, &m);
                    let ctx = lctx.check(&m, &mut ectx.ext);
                    assert_eq!(naive, ctx, "{} {} {}b", arch.name, layer.name, bits);
                    if naive.is_err() {
                        continue;
                    }
                    total_checked += 1;

                    let nest_naive: NestAnalysis = analyze(&arch, &layer, &m);
                    analyze_into(&lctx, &m, &mut ectx.ext, &mut ectx.nest);
                    assert_eq!(nest_naive.macs, ectx.nest.macs);
                    assert_eq!(nest_naive.pes_used, ectx.nest.pes_used);
                    assert_eq!(
                        nest_naive.accesses, ectx.nest.accesses,
                        "{} {} {}b: access counts diverged",
                        arch.name, layer.name, bits
                    );

                    let est_naive: Estimate = estimate(&arch, &layer, &q, &nest_naive);
                    estimate_into(&lctx, &ectx.nest, &mut ectx.est);
                    assert_eq!(
                        est_naive, ectx.est,
                        "{} {} {}b: estimate diverged",
                        arch.name, layer.name, bits
                    );
                    assert_eq!(est_naive.edp().to_bits(), ectx.est.edp().to_bits());
                }
            }
        }
    }
    assert!(total_checked > 100, "too few valid samples: {total_checked}");
}

/// Replicates the pre-refactor single-threaded search loop with the
/// naive per-draw functions.
fn reference_search(
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    cfg: &MapperConfig,
) -> (Option<u64>, u64, u64) {
    let q = &q.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(arch);
    let mut rng = Rng::new(cfg.seed ^ workload_hash(layer, q));
    let mut best: Option<f64> = None;
    let mut valid = 0u64;
    let mut draws = 0u64;
    while valid < cfg.valid_target && draws < cfg.max_draws {
        draws += 1;
        let m = space.random_mapping(layer, &mut rng);
        if check(arch, layer, q, &m).is_err() {
            continue;
        }
        valid += 1;
        let nest = analyze(arch, layer, &m);
        let est = estimate(arch, layer, q, &nest);
        let edp = est.edp();
        if best.map_or(true, |b| edp < b) {
            best = Some(edp);
        }
    }
    (best.map(f64::to_bits), valid, draws)
}

#[test]
fn single_shard_search_matches_naive_reference() {
    for (arch, layer) in [
        (toy(), ConvLayer::conv("t", 4, 8, 3, 8, 1)),
        (eyeriss(), ConvLayer::dw("d", 32, 3, 14, 1)),
    ] {
        for bits in [4u8, 8] {
            let q = LayerQuant::uniform(bits);
            let cfg = MapperConfig {
                valid_target: 80,
                max_draws: 80_000,
                seed: 23,
                shards: 1,
            };
            let (ref_best, ref_valid, ref_draws) = reference_search(&arch, &layer, &q, &cfg);
            let r = search(&arch, &layer, &q, &cfg);
            assert_eq!(r.best.map(|e| e.edp().to_bits()), ref_best, "{} {bits}b", arch.name);
            assert_eq!(r.valid, ref_valid);
            assert_eq!(r.draws, ref_draws);
        }
    }
}

#[test]
fn sharded_search_is_deterministic_per_shard_count() {
    let arch = eyeriss();
    let layer = ConvLayer::pw("p", 16, 32, 14);
    let q = LayerQuant::uniform(4);
    for shards in [1usize, 2, 3, 8] {
        let cfg = MapperConfig {
            valid_target: 160,
            max_draws: 160_000,
            seed: 77,
            shards,
        };
        let r1 = search(&arch, &layer, &q, &cfg);
        let r2 = search(&arch, &layer, &q, &cfg);
        assert_eq!(
            r1.best.as_ref().map(|e| e.edp().to_bits()),
            r2.best.as_ref().map(|e| e.edp().to_bits()),
            "shards={shards}"
        );
        assert_eq!(r1.valid, r2.valid, "shards={shards}");
        assert_eq!(r1.draws, r2.draws, "shards={shards}");
        assert_eq!(r1.best_mapping, r2.best_mapping, "shards={shards}");
        assert!(r1.valid >= 160, "shards={shards}: valid={}", r1.valid);
    }
}

#[test]
fn sharded_best_is_a_valid_mapping_with_plausible_edp() {
    // the sharded winner must verify against the naive checker/pricer
    let arch = eyeriss();
    let layer = ConvLayer::dw("d", 32, 3, 14, 1);
    let q = LayerQuant::uniform(8);
    let cfg = MapperConfig {
        valid_target: 200,
        max_draws: 200_000,
        seed: 5,
        shards: 4,
    };
    let r = search(&arch, &layer, &q, &cfg);
    let est = r.best.expect("should map");
    let m = r.best_mapping.expect("mapping returned");
    let qc = q.canonical(arch.word_bits, arch.bit_packing);
    check(&arch, &layer, &qc, &m).expect("winner must be valid");
    let nest = analyze(&arch, &layer, &m);
    let naive = estimate(&arch, &layer, &qc, &nest);
    assert_eq!(naive.edp().to_bits(), est.edp().to_bits());
}

#[test]
fn more_shards_never_reduce_total_valid_target_coverage() {
    // splitting the budget across shards must still reach the target on
    // an easy workload, whatever the shard count
    let arch = toy();
    let layer = ConvLayer::conv("t", 4, 8, 3, 8, 1);
    let q = LayerQuant::uniform(8);
    for shards in [1usize, 2, 5] {
        let cfg = MapperConfig {
            valid_target: 100,
            max_draws: 100_000,
            seed: 9,
            shards,
        };
        let r = search(&arch, &layer, &q, &cfg);
        assert!(r.valid >= 100, "shards={shards}: {}", r.valid);
        assert!(r.best.is_some());
    }
}
