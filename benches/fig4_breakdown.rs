//! Fig. 4: per-component energy breakdown of uniformly quantized
//! MobileNetV1 on Eyeriss, for x-bit settings x in {16, 8, 6, 5, 4, 3, 2}
//! (qa = qw = qo = x, best mapping per layer from random search).
//!
//! Paper shape to reproduce:
//!   * memory energy falls monotonically with x,
//!   * MAC energy stays constant (only the memory path is quantized),
//!   * 4-bit vs 8-bit: total energy down >~30%, memory energy down ~50%,
//!   * for x >= 6, bit-packing gives no benefit at word size 16
//!     (floor(16/x) stays 2), so 6b/8b memory energies coincide.
//!
//! Run: `cargo bench --bench fig4_breakdown`.

use qmap::coordinator::experiments::fig4_breakdown;
use qmap::coordinator::RunConfig;
use qmap::report;
use std::time::Instant;

fn main() {
    let rc = RunConfig::from_env().unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2)
    });
    println!("=== Fig. 4: energy breakdown, uniform x-bit MobileNetV1 on Eyeriss ===");
    let t0 = Instant::now();
    let rows = fig4_breakdown(&rc);
    let dt = t0.elapsed();

    let fmt: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mem = r.components_pj[0] + r.components_pj[1] + r.components_pj[2];
            vec![
                format!("{}b", r.bits),
                format!("{:.3e}", r.components_pj[0]),
                format!("{:.3e}", r.components_pj[1]),
                format!("{:.3e}", r.components_pj[2]),
                format!("{:.3e}", r.components_pj[3]),
                format!("{:.3e}", mem),
                format!("{:.3e}", r.total_pj),
            ]
        })
        .collect();
    print!(
        "{}",
        report::table(
            &["setting", "spads [pJ]", "buffers [pJ]", "DRAM [pJ]", "MAC [pJ]", "memory [pJ]", "total [pJ]"],
            &fmt
        )
    );

    // stacked ASCII bars (normalized to the 16-bit total)
    let max_total = rows.iter().map(|r| r.total_pj).fold(0.0, f64::max);
    println!("\nnormalized stacked bars (s=spads, b=buffers, D=DRAM, M=MAC):");
    for r in &rows {
        let bar_len = 64.0;
        let seg = |e: f64| ((e / max_total) * bar_len).round() as usize;
        let bar = format!(
            "{}{}{}{}",
            "s".repeat(seg(r.components_pj[0])),
            "b".repeat(seg(r.components_pj[1])),
            "D".repeat(seg(r.components_pj[2])),
            "M".repeat(seg(r.components_pj[3])),
        );
        println!("{:>3}b |{}", r.bits, bar);
    }

    // paper-shape checks
    let mem = |r: &qmap::coordinator::experiments::Fig4Row| {
        r.components_pj[0] + r.components_pj[1] + r.components_pj[2]
    };
    let get = |bits: u8| rows.iter().find(|r| r.bits == bits).unwrap();
    let (e8, e6, e4) = (get(8), get(6), get(4));
    let total_drop_4v8 = 1.0 - e4.total_pj / e8.total_pj;
    let mem_drop_4v8 = 1.0 - mem(e4) / mem(e8);
    let plateau = (mem(e8) - mem(e6)).abs() / mem(e8) < 1e-9;
    let monotone = rows.windows(2).all(|w| mem(&w[1]) <= mem(&w[0]) + 1e-9);
    println!("\n4b vs 8b: total energy -{:.1}% (paper: >32.5%)", total_drop_4v8 * 100.0);
    println!("4b vs 8b: memory energy -{:.1}% (paper: ~54.5%)", mem_drop_4v8 * 100.0);
    println!("6b == 8b memory energy (packing plateau at word 16): {plateau}");
    println!(
        "paper shape: {}",
        if monotone && plateau && total_drop_4v8 > 0.15 && mem_drop_4v8 > 0.3 {
            "REPRODUCED"
        } else {
            "MISMATCH"
        }
    );

    let csv_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.bits.to_string(),
                format!("{:.6e}", r.components_pj[0]),
                format!("{:.6e}", r.components_pj[1]),
                format!("{:.6e}", r.components_pj[2]),
                format!("{:.6e}", r.components_pj[3]),
                format!("{:.6e}", r.total_pj),
            ]
        })
        .collect();
    let path = report::write_results(
        "fig4_breakdown.csv",
        &report::csv(&["bits", "spads_pj", "buffers_pj", "dram_pj", "mac_pj", "total_pj"], &csv_rows),
    );
    let svg = report::svg::stacked_bars(
        "Fig 4: energy breakdown, uniform x-bit MobileNetV1 on Eyeriss",
        &rows.iter().map(|r| format!("{}b", r.bits)).collect::<Vec<_>>(),
        &["spads", "buffers", "DRAM", "MAC"],
        &rows.iter().map(|r| r.components_pj.to_vec()).collect::<Vec<_>>(),
    );
    report::write_results("fig4.svg", &svg);
    println!("[{dt:.2?}] wrote {} (+ fig4.svg)", path.display());
}
