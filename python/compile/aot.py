"""AOT compile path: lower the L2 train/eval steps to HLO **text** and
dump initial parameters + a JSON manifest for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: the
``xla`` crate's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit
instruction ids; the text parser reassigns ids (see
/opt/xla-example/README.md). Lowered with ``return_tuple=True`` so the
Rust side unwraps with ``to_tuple{N}``.

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile does
this); it is a no-op for unchanged inputs thanks to the Makefile
dependency list.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

BATCH = 32


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """Lower to HLO text.

    ``return_tuple=False`` is used for the single-result ``train_step``
    artifact: its root is then a plain f32[PARAM_SIZE] array, which PJRT
    returns as ONE on-device buffer that the Rust runtime feeds straight
    back into the next ``execute_b`` call — no host round-trip for the
    parameters (§Perf: Literal-marshaling 0.85 MB in+out cost ~290
    ms/step; this PJRT (xla_extension 0.5.1) does NOT untuple
    multi-output roots, so the loss is intentionally NOT returned by the
    train artifact — the runtime fetches it from ``eval_step`` when a
    loss curve is wanted)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def lower_all(batch: int = BATCH):
    """Lower train_step and eval_step; returns {name: hlo_text}."""
    p = jax.ShapeDtypeStruct((model.PARAM_SIZE,), jnp.float32)
    x = jax.ShapeDtypeStruct((batch, model.IMG, model.IMG, model.IN_CH), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    q = jax.ShapeDtypeStruct((model.NUM_LAYERS,), jnp.float32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)

    # train artifact returns ONLY new_params (see to_hlo_text docstring)
    def train_params_only(p, x, y, qa, qw, lr):
        return model.train_step(p, x, y, qa, qw, lr)[0]

    train = jax.jit(train_params_only).lower(p, x, y, q, q, lr)
    evals = jax.jit(model.eval_step).lower(p, x, y, q, q)
    return {
        "train_step": to_hlo_text(train, return_tuple=False),
        "eval_step": to_hlo_text(evals),
    }


def manifest(batch: int) -> dict:
    return {
        "model": "scaled_mobilenet_v1",
        "num_layers": model.NUM_LAYERS,
        "param_size": model.PARAM_SIZE,
        "batch": batch,
        "img": model.IMG,
        "in_ch": model.IN_CH,
        "num_classes": model.NUM_CLASSES,
        "use_pallas": model.USE_PALLAS,
        "artifacts": {
            "train_step": {
                "file": "train_step.hlo.txt",
                "inputs": ["params", "x", "y", "qa", "qw", "lr"],
                "outputs": ["new_params"],
            },
            "eval_step": {
                "file": "eval_step.hlo.txt",
                "inputs": ["params", "x", "y", "qa", "qw"],
                "outputs": ["correct", "loss"],
            },
        },
        "params": [
            {"name": n, "shape": list(s), "offset": o}
            for n, s, o in model.PARAM_SPEC
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    hlos = lower_all(args.batch)
    for name, text in hlos.items():
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    params = model.init_params(args.seed)
    import numpy as np

    raw = np.asarray(params, dtype="<f4").tobytes()
    with open(os.path.join(args.out, "params_init.bin"), "wb") as f:
        f.write(raw)
    print(f"wrote params_init.bin ({len(raw)} bytes, {params.size} f32)")

    with open(os.path.join(args.out, "model_meta.json"), "w") as f:
        json.dump(manifest(args.batch), f, indent=2)
    print("wrote model_meta.json")


if __name__ == "__main__":
    main()
