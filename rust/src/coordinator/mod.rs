//! L3 coordinator: experiment drivers shared by the CLI (`qmap <cmd>`)
//! and the `benches/` harnesses that regenerate every paper table and
//! figure. Each function returns structured rows; formatting lives in
//! `crate::report` and the callers.

pub mod experiments;

use crate::mapper::MapperConfig;
use crate::nsga::NsgaConfig;
use crate::objective::ObjectiveSpec;

/// Global experiment knobs with paper-faithful defaults, scaled for a
/// laptop-class run (DESIGN.md §3: budget substitution).
#[derive(Debug, Clone, Copy)]
pub struct RunConfig {
    pub mapper: MapperConfig,
    pub nsga: NsgaConfig,
    /// The search's objective space (default: the paper's `edp,error`;
    /// `QMAP_OBJECTIVES` / `--objectives` select another).
    pub objectives: ObjectiveSpec,
    /// Worker threads for parallel candidate evaluation.
    pub threads: usize,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            mapper: MapperConfig::default(),
            nsga: NsgaConfig::default(),
            objectives: ObjectiveSpec::default(),
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            seed: 0x9A9E12,
        }
    }
}

// `--workers` / `QMAP_WORKERS` parsing lives with its consumer now:
// `engine::WorkerSource::parse` handles both the comma-separated form
// and the `@file` elastic-fleet form (the former `parse_worker_list` /
// `workers_from_env` helpers here were an exact subset and have been
// retired to keep one implementation).

impl RunConfig {
    /// Resolve a profile by name: `fast` (CI smoke) | `default` |
    /// `full` (paper-faithful budgets). An unknown name is an error (it
    /// used to be silently treated as `default`, which made typos like
    /// `QMAP_PROFILE=fastt` run 30x longer than intended with no
    /// warning).
    pub fn from_profile(name: &str) -> Result<Self, String> {
        match name {
            "fast" => Ok(RunConfig::fast()),
            "full" => Ok(RunConfig::full()),
            "default" | "" => Ok(RunConfig::default()),
            other => Err(format!(
                "unknown QMAP_PROFILE '{other}' (valid profiles: fast, default, full)"
            )),
        }
    }

    /// Profile selection for the bench harnesses: `QMAP_PROFILE` (see
    /// [`RunConfig::from_profile`]) with `QMAP_THREADS` / `QMAP_SEED` /
    /// `QMAP_SHARDS` / `QMAP_OBJECTIVES` overrides. A malformed
    /// objective spec is an error, not a silent fallback to the
    /// default axes.
    pub fn from_env() -> Result<Self, String> {
        let mut rc = match std::env::var("QMAP_PROFILE") {
            Ok(p) => Self::from_profile(&p)?,
            Err(_) => RunConfig::default(),
        };
        if let Some(spec) = ObjectiveSpec::from_env()? {
            rc.objectives = spec;
        }
        if let Ok(t) = std::env::var("QMAP_THREADS") {
            if let Ok(t) = t.parse() {
                rc.threads = t;
            }
        }
        if let Ok(s) = std::env::var("QMAP_SEED") {
            if let Ok(s) = s.parse() {
                rc.seed = s;
            }
        }
        if let Ok(s) = std::env::var("QMAP_SHARDS") {
            if let Ok(s) = s.parse() {
                rc.mapper.shards = s;
            }
        }
        Ok(rc)
    }

    /// Paper-faithful budgets (2000 valid mappings per workload,
    /// |P|=32, |Q|=16, 20 generations) — minutes-scale on a laptop.
    pub fn full() -> Self {
        RunConfig {
            mapper: MapperConfig {
                valid_target: 2_000,
                max_draws: 2_000_000,
                seed: 7,
                // population-level parallelism already saturates the
                // cores; per-workload sharding stays off by default
                shards: 1,
            },
            nsga: NsgaConfig::default(),
            ..RunConfig::default()
        }
    }

    /// A fast profile for tests and smoke runs.
    pub fn fast() -> Self {
        RunConfig {
            mapper: MapperConfig {
                valid_target: 60,
                max_draws: 60_000,
                seed: 1,
                shards: 1,
            },
            nsga: NsgaConfig {
                population: 12,
                offspring: 8,
                generations: 6,
                ..NsgaConfig::default()
            },
            objectives: ObjectiveSpec::default(),
            threads: 4,
            seed: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // `from_profile` is pure, so these run without touching the
    // process-global environment (setenv during parallel tests is a
    // data race on glibc).
    #[test]
    fn known_profiles_resolve() {
        let fast = RunConfig::from_profile("fast").expect("fast is a valid profile");
        assert_eq!(fast.mapper.valid_target, RunConfig::fast().mapper.valid_target);
        let full = RunConfig::from_profile("full").expect("full is a valid profile");
        assert_eq!(full.mapper.max_draws, RunConfig::full().mapper.max_draws);
        let def = RunConfig::from_profile("default").expect("default is a valid profile");
        assert_eq!(def.mapper.valid_target, RunConfig::default().mapper.valid_target);
        assert_eq!(
            RunConfig::from_profile("").expect("empty means default").mapper.valid_target,
            RunConfig::default().mapper.valid_target
        );
    }

    #[test]
    fn unknown_profile_is_rejected_with_the_valid_list() {
        let err = RunConfig::from_profile("warp-speed")
            .expect_err("unknown profile must be rejected");
        assert!(err.contains("warp-speed"), "{err}");
        assert!(
            err.contains("fast") && err.contains("default") && err.contains("full"),
            "error must list the valid profiles: {err}"
        );
    }
}
