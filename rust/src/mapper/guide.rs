//! Validity-rate guidance: deterministic per-workload (valid, drawn)
//! counts and the exact-sum budget apportionment they drive.
//!
//! The mapper's draws are blind — every workload gets the same
//! `valid_target`/`max_draws` budget and the scheduler's only signal is
//! layer size. But the search itself keeps measuring how *hard* each
//! workload is: every merged [`super::MapperResult`] reports how many
//! draws its valid mappings cost. [`GuideState`] folds those counts per
//! workload hash, and [`GuideState::expected_draws`] turns them into an
//! estimated draws-to-target that `engine::driver::order_jobs` uses to
//! start the hungriest jobs first (longest-job-first placement shrinks
//! the generation tail).
//!
//! Determinism contract: guidance is **placement-only**. The counts are
//! commutative saturating sums, so any execution order folds to the same
//! state; the state only ever reorders jobs and never touches
//! [`super::shard_plan`]'s budgets for result-bearing searches — the
//! candidate streams, and therefore every Pareto front, are bit-identical
//! to the unguided engine. `SchedPolicy` already pins that invariant
//! (`sched_policy_never_changes_results`), and the guided
//! `engine_stateful` scripts re-pin it end to end.
//!
//! The state rides the checkpoint journal (an optional `guide` key in
//! the mark frame — see `engine::checkpoint`) and `proto::batch`
//! (an optional per-workload rate hint), so resumed drivers and elastic
//! fleets schedule from the same history.

use super::MapperConfig;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Per-workload `(valid, drawn)` counts, keyed by the workload hash
/// (`super::workload_hash`). `BTreeMap` keeps iteration — and thus the
/// serialized form — deterministic.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GuideState {
    counts: BTreeMap<u64, (u64, u64)>,
}

impl GuideState {
    pub fn new() -> GuideState {
        GuideState::default()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    pub fn len(&self) -> usize {
        self.counts.len()
    }

    /// Fold one search outcome (or negative-cache draw budget) into the
    /// workload's counts. Saturating: the counts are a heuristic signal,
    /// and a fleet that somehow overflows u64 draws must degrade to
    /// "very hard", not wrap to "easy".
    pub fn note(&mut self, whash: u64, valid: u64, drawn: u64) {
        let e = self.counts.entry(whash).or_insert((0, 0));
        e.0 = e.0.saturating_add(valid);
        e.1 = e.1.saturating_add(drawn);
    }

    /// Fold another guide state in (commutative, associative — the fold
    /// order across shards/hosts cannot change the result).
    pub fn merge(&mut self, other: &GuideState) {
        for (&whash, &(valid, drawn)) in &other.counts {
            self.note(whash, valid, drawn);
        }
    }

    /// The raw `(valid, drawn)` counts for one workload, if any.
    pub fn rate(&self, whash: u64) -> Option<(u64, u64)> {
        self.counts.get(&whash).copied()
    }

    /// Estimated draws needed to reach `cfg.valid_target` valid
    /// mappings on this workload: `ceil(valid_target x drawn / valid)`,
    /// clamped to `[1, cfg.max_draws]`. Unseen workloads — and ones
    /// that never produced a valid mapping — estimate the full draw
    /// budget, so cold guides rank every job equally and the scheduler
    /// falls back to its historical key.
    pub fn expected_draws(&self, whash: u64, cfg: &MapperConfig) -> u64 {
        match self.counts.get(&whash) {
            Some(&(valid, drawn)) if valid > 0 => {
                let est = (cfg.valid_target as u128 * drawn as u128).div_ceil(valid as u128);
                est.min(cfg.max_draws.max(1) as u128).max(1) as u64
            }
            _ => cfg.max_draws,
        }
    }

    /// Iterate entries in deterministic (ascending-hash) order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, (u64, u64))> + '_ {
        self.counts.iter().map(|(&k, &v)| (k, v))
    }

    /// Wire/journal form: an array of `{whash, valid, drawn}` objects
    /// in ascending hash order, every `u64` as a hex string (counts can
    /// legitimately exceed 2^53).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.counts
                .iter()
                .map(|(&whash, &(valid, drawn))| {
                    Json::obj(vec![
                        ("whash", Json::hex_u64(whash)),
                        ("valid", Json::hex_u64(valid)),
                        ("drawn", Json::hex_u64(drawn)),
                    ])
                })
                .collect(),
        )
    }

    /// Total decoder for [`GuideState::to_json`]: malformed input is an
    /// `Err`, never a panic (this parses journal bytes and network
    /// frames). Duplicate hashes fold together rather than erroring —
    /// a merged journal must still load.
    pub fn from_json(v: &Json) -> Result<GuideState, String> {
        let mut g = GuideState::new();
        for e in v.as_arr().ok_or("guide: not an array")? {
            g.note(
                e.get("whash").as_hex_u64("guide whash")?,
                e.get("valid").as_hex_u64("guide valid")?,
                e.get("drawn").as_hex_u64("guide drawn")?,
            );
        }
        Ok(g)
    }
}

/// Apportion `total` across `weights` by largest remainder: entry `i`
/// gets `floor(total x w_i / sum(w))`, and the residue (always fewer
/// units than entries) goes to the largest fractional remainders, ties
/// to the lowest index. The result always sums to exactly `total` —
/// the rounding bug class [`super::shard_plan`] must never exhibit
/// (a shard plan whose draw budgets don't reassemble `max_draws` would
/// silently change `MapperResult::draws`).
///
/// All-zero (or empty) weights fall back to the uniform split
/// `total / n + (i < total % n)`; uniform *non-zero* weights reduce to
/// the same expression (equal remainders, ties to the lowest index), so
/// the historical plan is reproduced bit-for-bit.
pub fn apportion(total: u64, weights: &[u64]) -> Vec<u64> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let n64 = n as u64;
    let wsum: u128 = weights.iter().map(|&w| w as u128).sum();
    if wsum == 0 {
        return (0..n64).map(|i| total / n64 + u64::from(i < total % n64)).collect();
    }
    let total = total as u128;
    let mut out = Vec::with_capacity(n);
    let mut rems: Vec<(u128, usize)> = Vec::with_capacity(n);
    let mut assigned = 0u128;
    for (i, &w) in weights.iter().enumerate() {
        let num = total * w as u128;
        out.push((num / wsum) as u64);
        assigned += num / wsum;
        rems.push((num % wsum, i));
    }
    let mut leftover = (total - assigned) as usize;
    // sum of remainders = leftover x wsum with every remainder < wsum,
    // so there are always at least `leftover` positive remainders —
    // zero-weight entries never receive residue units
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, i) in &rems {
        if leftover == 0 {
            break;
        }
        out[i] += 1;
        leftover -= 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn apportion_sums_exactly_over_random_counts_and_budgets() {
        // the satellite property: random shard counts x budgets x
        // weight profiles, the apportioned columns always reassemble
        // the exact total
        let mut rng = Rng::new(0xA990_0471);
        for _ in 0..500 {
            let n = 1 + (rng.next_u64() % 64) as usize;
            let total = rng.next_u64() % 10_000_000;
            let weights: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1_000).collect();
            let parts = apportion(total, &weights);
            assert_eq!(parts.len(), n);
            assert_eq!(parts.iter().sum::<u64>(), total, "n={n} total={total}");
            // zero-weight entries never receive residue units
            for (i, &w) in weights.iter().enumerate() {
                if w == 0 && weights.iter().any(|&x| x > 0) {
                    assert_eq!(parts[i], 0, "zero weight got budget");
                }
            }
        }
    }

    #[test]
    fn apportion_uniform_reproduces_the_legacy_split() {
        for n in 1..=17usize {
            for total in [0u64, 1, 2, 7, 100, 2_001, 1 << 40] {
                let uniform = apportion(total, &vec![1u64; n]);
                let legacy: Vec<u64> = (0..n as u64)
                    .map(|i| total / n as u64 + u64::from(i < total % n as u64))
                    .collect();
                assert_eq!(uniform, legacy, "n={n} total={total}");
                // all-zero weights take the same fallback
                assert_eq!(apportion(total, &vec![0u64; n]), legacy);
            }
        }
    }

    #[test]
    fn apportion_is_proportional_and_total_on_extremes() {
        assert!(apportion(100, &[]).is_empty());
        assert_eq!(apportion(0, &[3, 5]), vec![0, 0]);
        // 2:1 weights: the heavy shard gets twice the budget
        assert_eq!(apportion(90, &[2, 1]), vec![60, 30]);
        // huge totals and weights must not overflow (u128 internally)
        let parts = apportion(u64::MAX, &[u64::MAX, u64::MAX, 1]);
        assert_eq!(parts.iter().sum::<u64>(), u64::MAX);
    }

    fn cfg() -> MapperConfig {
        MapperConfig {
            valid_target: 100,
            max_draws: 10_000,
            seed: 1,
            shards: 2,
        }
    }

    #[test]
    fn expected_draws_ranks_hard_workloads_above_easy_ones() {
        let mut g = GuideState::new();
        assert_eq!(g.expected_draws(1, &cfg()), 10_000, "unseen = full budget");
        g.note(1, 500, 1_000); // easy: 50% valid
        g.note(2, 10, 8_000); // hard: 0.125% valid
        g.note(3, 0, 9_999); // never valid: worst case
        let e1 = g.expected_draws(1, &cfg());
        let e2 = g.expected_draws(2, &cfg());
        let e3 = g.expected_draws(3, &cfg());
        assert_eq!(e1, 200, "ceil(100 x 1000 / 500)");
        assert_eq!(e2, 10_000, "ceil(100 x 8000 / 10) clamps to max_draws");
        assert_eq!(e3, 10_000, "zero-valid = full budget");
        assert!(e1 < e2);
        // degenerate config: the estimate stays in [1, max(1, max_draws)]
        let tiny = MapperConfig {
            valid_target: 0,
            max_draws: 0,
            ..cfg()
        };
        assert_eq!(g.expected_draws(1, &tiny), 1);
    }

    #[test]
    fn guide_folds_commutatively_and_roundtrips_json() {
        let mut a = GuideState::new();
        a.note(7, 10, 100);
        a.note(9, 5, 50);
        let mut b = GuideState::new();
        b.note(9, 5, 50);
        b.note(7, 4, 40);
        b.note(7, 6, 60);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "merge must be commutative");
        assert_eq!(ab.rate(7), Some((20, 200)));
        assert_eq!(ab.len(), 2);
        // through the value model AND through actual bytes
        let text = ab.to_json().to_string();
        let back = GuideState::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, ab);
        // saturating, never wrapping
        let mut s = GuideState::new();
        s.note(1, u64::MAX, u64::MAX);
        s.note(1, 1, 1);
        assert_eq!(s.rate(1), Some((u64::MAX, u64::MAX)));
        // malformed wire data is an error, never a panic
        assert!(GuideState::from_json(&Json::Num(1.0)).is_err());
        assert!(GuideState::from_json(&Json::Arr(vec![Json::Null])).is_err());
        // empty state round-trips to an empty array
        assert_eq!(GuideState::new().to_json().to_string(), "[]");
    }
}
