//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop`; on failure it performs a simple greedy shrink by
//! retrying with re-generated "smaller" candidates drawn from the same
//! generator and reports the seed so the case can be replayed.

use super::rng::Rng;

/// Run a property over randomly generated inputs.
///
/// * `gen` maps an RNG to an input value.
/// * `prop` returns `Err(msg)` to signal a violated property.
pub fn check<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut r = root.split(case as u64);
        let input = gen(&mut r);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Like `check` but the property also receives an RNG (for randomized
/// assertions inside the property body).
pub fn check_with_rng<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T, &mut Rng) -> Result<(), String>,
) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut r = root.split(case as u64);
        let input = gen(&mut r);
        let mut r2 = root.split(0x5EED ^ case as u64);
        if let Err(msg) = prop(&input, &mut r2) {
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 200, |r| r.range(0, 100), |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        check(2, 50, |r| r.range(0, 10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err(format!("{x} >= 5"))
            }
        });
    }
}
