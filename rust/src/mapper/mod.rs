//! The mapper: searches the mapspace of one workload for the best
//! mapping under a quantization setting.
//!
//! Mirrors the paper's Timeloop configuration: "random search with
//! termination condition set to finding 2000 valid mappings per
//! workload", the best mapping selected by minimum EDP. A per-workload
//! result cache (the paper's §III-A caching mechanism) makes repeated
//! NSGA-II evaluations of similar genomes cheap.

pub mod cache;
pub mod gamma;

use crate::arch::Arch;
use crate::energy::{estimate_into, Estimate};
use crate::mapping::mapspace::MapSpace;
use crate::mapping::{LayerContext, Mapping};
use crate::nest::{analyze_into, NestAnalysis};
use crate::quant::LayerQuant;
use crate::util::rng::Rng;
use crate::workload::ConvLayer;

/// Mapper configuration.
#[derive(Debug, Clone, Copy)]
pub struct MapperConfig {
    /// Stop after this many *valid* mappings have been evaluated
    /// (paper: 2000).
    pub valid_target: u64,
    /// Hard cap on candidate draws (valid or not), to bound pathological
    /// workloads where validity is rare.
    pub max_draws: u64,
    /// RNG seed (combined with a workload hash for determinism).
    pub seed: u64,
    /// Parallel search shards for one workload (0 = one per available
    /// core). Targets and draw budgets split across shards; each shard
    /// derives its own seed from (seed, workload hash, shard index), so
    /// results are deterministic for a fixed (seed, shards) pair.
    pub shards: usize,
}

impl Default for MapperConfig {
    fn default() -> Self {
        MapperConfig {
            valid_target: 2000,
            max_draws: 400_000,
            seed: 0x51AB5EED,
            shards: 1,
        }
    }
}

/// Reusable per-thread scratch for the allocation-free hot path: one
/// candidate `Mapping`, the factorization slot buffer, the cumulative
/// tile-extent buffer, and the nest/estimate output slots. Build once
/// per (thread, workload) and reuse across candidate draws — the
/// steady-state loop performs zero heap allocations per draw.
pub struct EvalContext {
    pub mapping: Mapping,
    pub fbuf: Vec<u64>,
    pub ext: Vec<[u64; 7]>,
    pub nest: NestAnalysis,
    pub est: Estimate,
}

impl EvalContext {
    pub fn for_arch(arch: &Arch) -> Self {
        let space = MapSpace::of(arch);
        Self::with_dims(arch.levels.len(), space.slots())
    }

    pub fn with_dims(num_levels: usize, slots: usize) -> Self {
        EvalContext {
            mapping: Mapping::unit(num_levels),
            fbuf: vec![1; slots],
            ext: Vec::with_capacity(num_levels),
            nest: NestAnalysis::empty(),
            est: Estimate::empty(),
        }
    }
}

/// Outcome of a mapper search on one workload.
#[derive(Debug, Clone)]
pub struct MapperResult {
    /// Best (minimum-EDP) estimate found; `None` if no valid mapping.
    pub best: Option<Estimate>,
    /// The mapping achieving `best`.
    pub best_mapping: Option<Mapping>,
    /// Number of valid mappings encountered.
    pub valid: u64,
    /// Number of candidates drawn.
    pub draws: u64,
}

/// Per-shard search outcome (internal).
struct ShardResult {
    /// (EDP, estimate, mapping) of the shard's winner.
    best: Option<(f64, Estimate, Mapping)>,
    valid: u64,
    draws: u64,
}

/// One shard of the random search: draws candidates through the
/// allocation-free context path until its share of the valid-mapping
/// target (or draw budget) is exhausted. Within a shard the first
/// strictly-lower EDP wins, so the result is deterministic in the seed.
fn search_shard(
    space: &MapSpace,
    lctx: &LayerContext,
    seed: u64,
    valid_target: u64,
    max_draws: u64,
) -> ShardResult {
    let mut ctx = EvalContext::with_dims(lctx.num_levels, space.slots());
    let mut rng = Rng::new(seed);
    let mut best: Option<(f64, Estimate, Mapping)> = None;
    let mut valid = 0u64;
    let mut draws = 0u64;

    while valid < valid_target && draws < max_draws {
        draws += 1;
        space.random_mapping_into(lctx, &mut rng, &mut ctx.fbuf, &mut ctx.mapping);
        if lctx.check(&ctx.mapping, &mut ctx.ext).is_err() {
            continue;
        }
        valid += 1;
        analyze_into(lctx, &ctx.mapping, &mut ctx.ext, &mut ctx.nest);
        estimate_into(lctx, &ctx.nest, &mut ctx.est);
        let edp = ctx.est.edp();
        match &mut best {
            Some((b, be, bm)) => {
                if edp < *b {
                    *b = edp;
                    be.copy_from(&ctx.est);
                    bm.copy_from(&ctx.mapping);
                }
            }
            None => best = Some((edp, ctx.est.clone(), ctx.mapping.clone())),
        }
    }

    ShardResult { best, valid, draws }
}

/// Resolve the configured shard count (0 = auto) and cap it so no shard
/// is left without a share of the valid-mapping target.
fn effective_shards(cfg: &MapperConfig) -> usize {
    let s = if cfg.shards == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.shards
    };
    s.max(1).min(cfg.valid_target.clamp(1, 1024) as usize)
}

/// Random-search the mapspace of `(layer, q)` on `arch`.
///
/// Bit-widths are canonicalized to their packing-equivalence class first
/// (see [`LayerQuant::canonical`]): the engine's capacity and energy
/// models depend on `q` only through the pack factor, so equivalent
/// settings must explore identical mapspaces (and share cache entries).
///
/// With `cfg.shards > 1` the valid-mapping target and draw budget split
/// across that many threads, each with a seed derived from
/// `(cfg.seed, workload, shard index)`, and the shard minima merge by
/// minimum EDP with ties resolved to the lowest shard index (within a
/// shard the strict `<` keeps the earliest winner) — deterministic for
/// a fixed (seed, shards) pair. `shards == 1` reproduces the
/// single-threaded candidate stream exactly.
pub fn search(arch: &Arch, layer: &ConvLayer, q: &LayerQuant, cfg: &MapperConfig) -> MapperResult {
    let q = &q.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(arch);
    let lctx = LayerContext::new(arch, layer, q);
    let base_seed = cfg.seed ^ workload_hash(layer, q);
    let shards = effective_shards(cfg);

    let results: Vec<ShardResult> = if shards <= 1 {
        vec![search_shard(&space, &lctx, base_seed, cfg.valid_target, cfg.max_draws)]
    } else {
        let n = shards as u64;
        let mut slots: Vec<Option<ShardResult>> = (0..shards).map(|_| None).collect();
        std::thread::scope(|s| {
            for (i, slot) in slots.iter_mut().enumerate() {
                let space = &space;
                let lctx = &lctx;
                let iu = i as u64;
                let target = cfg.valid_target / n + u64::from(iu < cfg.valid_target % n);
                let draws = cfg.max_draws / n + u64::from(iu < cfg.max_draws % n);
                let seed = base_seed ^ iu.wrapping_mul(0x9E3779B97F4A7C15);
                s.spawn(move || {
                    *slot = Some(search_shard(space, lctx, seed, target, draws));
                });
            }
        });
        slots.into_iter().map(|r| r.expect("shard completed")).collect()
    };

    // deterministic merge: iterate shards in index order and keep the
    // first strictly-minimum EDP (ties go to the lowest shard index).
    let mut valid = 0u64;
    let mut draws = 0u64;
    let mut best: Option<(f64, Estimate, Mapping)> = None;
    for r in results {
        valid += r.valid;
        draws += r.draws;
        if let Some((edp, est, m)) = r.best {
            if best.as_ref().map_or(true, |(b, _, _)| edp < *b) {
                best = Some((edp, est, m));
            }
        }
    }

    match best {
        Some((_, est, m)) => MapperResult {
            best: Some(est),
            best_mapping: Some(m),
            valid,
            draws,
        },
        None => MapperResult {
            best: None,
            best_mapping: None,
            valid,
            draws,
        },
    }
}

/// Stable 64-bit hash of a workload + quantization (cache key and seed
/// derivation). FNV-1a over the canonical fields.
pub fn workload_hash(layer: &ConvLayer, q: &LayerQuant) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut feed = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for &d in &layer.dims {
        feed(d);
    }
    feed(layer.stride.0);
    feed(layer.stride.1);
    feed(layer.kind as u64);
    feed(q.qa as u64);
    feed(q.qw as u64);
    feed(q.qo as u64);
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{eyeriss, toy};
    use crate::workload::ConvLayer;

    #[test]
    fn finds_valid_mappings_on_toy() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let cfg = MapperConfig {
            valid_target: 200,
            max_draws: 100_000,
            seed: 1,
            shards: 1,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert!(r.valid >= 200);
        assert!(r.best.is_some());
        assert!(r.best.unwrap().edp() > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let cfg = MapperConfig {
            valid_target: 100,
            max_draws: 50_000,
            seed: 7,
            shards: 1,
        };
        let q = LayerQuant::uniform(4);
        let r1 = search(&a, &l, &q, &cfg);
        let r2 = search(&a, &l, &q, &cfg);
        assert_eq!(r1.best.map(|e| e.edp()), r2.best.map(|e| e.edp()));
        assert_eq!(r1.valid, r2.valid);
    }

    #[test]
    fn sharded_search_is_deterministic() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(4);
        for shards in [2usize, 4] {
            let cfg = MapperConfig {
                valid_target: 120,
                max_draws: 60_000,
                seed: 7,
                shards,
            };
            let r1 = search(&a, &l, &q, &cfg);
            let r2 = search(&a, &l, &q, &cfg);
            assert_eq!(
                r1.best.as_ref().map(|e| e.edp().to_bits()),
                r2.best.as_ref().map(|e| e.edp().to_bits()),
                "shards={shards}"
            );
            assert_eq!(r1.valid, r2.valid);
            assert_eq!(r1.draws, r2.draws);
            assert!(r1.valid >= 120, "shards={shards} valid={}", r1.valid);
            assert_eq!(r1.best_mapping, r2.best_mapping);
        }
    }

    #[test]
    fn sharded_targets_sum_to_config() {
        // draws split exactly: on a never-valid workload every shard
        // exhausts its share and the totals reassemble the budget
        let a = toy();
        let l = ConvLayer::conv("t", 97, 89, 1, 13, 1); // awkward primes
        let cfg = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 2_001, // deliberately not divisible by shards
            seed: 5,
            shards: 4,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert_eq!(r.draws, 2_001);
    }

    #[test]
    fn lower_bits_find_lower_edp_on_eyeriss() {
        // the synergy effect end-to-end through the mapper
        let a = eyeriss();
        let l = ConvLayer::dw("dw2", 32, 3, 112, 1);
        let cfg = MapperConfig {
            valid_target: 300,
            max_draws: 300_000,
            seed: 3,
            shards: 1,
        };
        let e16 = search(&a, &l, &LayerQuant::uniform(16), &cfg);
        let e4 = search(&a, &l, &LayerQuant::uniform(4), &cfg);
        let b16 = e16.best.expect("16b should map").edp();
        let b4 = e4.best.expect("4b should map").edp();
        assert!(b4 < b16, "edp4={b4} edp16={b16}");
    }

    #[test]
    fn hash_distinguishes_quant_and_shape() {
        let l1 = ConvLayer::conv("a", 4, 8, 3, 8, 1);
        let l2 = ConvLayer::conv("b", 8, 8, 3, 8, 1);
        let q8 = LayerQuant::uniform(8);
        let q4 = LayerQuant::uniform(4);
        assert_ne!(workload_hash(&l1, &q8), workload_hash(&l1, &q4));
        assert_ne!(workload_hash(&l1, &q8), workload_hash(&l2, &q8));
        // name does NOT affect the key: same shape+q hits the same cache
        let l1b = ConvLayer::conv("other_name", 4, 8, 3, 8, 1);
        assert_eq!(workload_hash(&l1, &q8), workload_hash(&l1b, &q8));
    }

    #[test]
    fn impossible_workload_returns_none() {
        // single PE spad of 16 words can't hold even one weight at 16b if
        // we also forbid DRAM-resident loops? Actually DRAM-heavy always
        // works; make a level-0 mandatory overflow by using a huge R so
        // that any unit tile... unit tiles always fit. So instead: check
        // that max_draws bounds the search on a workload with rare
        // validity rather than hanging.
        let a = toy();
        let l = ConvLayer::conv("t", 97, 89, 1, 13, 1); // awkward primes
        let cfg = MapperConfig {
            valid_target: u64::MAX,
            max_draws: 2_000,
            seed: 5,
            shards: 1,
        };
        let r = search(&a, &l, &LayerQuant::uniform(8), &cfg);
        assert_eq!(r.draws, 2_000);
    }
}
