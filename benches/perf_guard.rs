//! CI bench-regression guard: compares the fast-profile
//! `perf_hotpath` record (`BENCH_perf.json`, written by the bench that
//! must run first) against the committed floors in
//! `BENCH_baseline.json`, and exits non-zero when any guarded row
//! regresses by more than the configured tolerance.
//!
//! Only machine-portable *ratios* are guarded (hot-path speedup,
//! batched-vs-scalar speedup, engine scaling, tail improvement,
//! pipeline speedup, checkpoint journal-vs-snapshot) — absolute
//! millisecond rows vary with the runner and would make the guard
//! flaky. The baseline values are deliberately conservative floors,
//! not aspirations: the guard exists to catch a real regression (a
//! lost fast path, an accidental serialization), not to fail on
//! scheduler noise.
//!
//! Next to the floors, the baseline can declare `ceilings` — rows that
//! fail when they *rise* past `want * (1 + tolerance)`. The first is
//! `trace_overhead_pct`: the cost of an attached JSONL trace on a full
//! generation, capped so event emission can never creep into the hot
//! path unnoticed.
//!
//! Run: `cargo bench --bench perf_hotpath && cargo bench --bench
//! perf_guard` (the CI smoke does exactly this, fast profile).

use qmap::util::json::parse;

fn read(path: &str) -> String {
    match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bench guard: {path}: {e} (run `cargo bench --bench perf_hotpath` first)");
            std::process::exit(2);
        }
    }
}

fn main() {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
    let perf_path = format!("{root}/BENCH_perf.json");
    let base_path = format!("{root}/BENCH_baseline.json");
    // BENCH_perf.json is generated, not tracked — on a fresh checkout a
    // bare `cargo bench` runs this target BEFORE perf_hotpath
    // (alphabetical order) and must not abort the whole bench run.
    // CI sets QMAP_GUARD_REQUIRE=1 right after running perf_hotpath,
    // where a missing record is a genuine failure.
    if !std::path::Path::new(&perf_path).exists() {
        if std::env::var("QMAP_GUARD_REQUIRE").is_ok() {
            eprintln!("bench guard: {perf_path} missing (perf_hotpath must run first)");
            std::process::exit(2);
        }
        println!("bench guard: no {perf_path} yet — skipping (run perf_hotpath first)");
        return;
    }
    let perf = parse(&read(&perf_path)).unwrap_or_else(|e| {
        eprintln!("bench guard: {perf_path}: {e}");
        std::process::exit(2);
    });
    let base = parse(&read(&base_path)).unwrap_or_else(|e| {
        eprintln!("bench guard: {base_path}: {e}");
        std::process::exit(2);
    });
    let tolerance = base.get("tolerance").as_f64().unwrap_or(0.25);
    let Some(guards) = base.get("guards").as_obj() else {
        eprintln!("bench guard: {base_path} has no guards object");
        std::process::exit(2);
    };
    println!(
        "bench guard: {} row(s), fail below baseline - {:.0}%",
        guards.len(),
        tolerance * 100.0
    );
    let mut failed = 0usize;
    for (key, want) in guards {
        let Some(want) = want.as_f64() else {
            eprintln!("  {key:<28} baseline is not a number — guard misconfigured");
            failed += 1;
            continue;
        };
        let Some(got) = perf.get(key).as_f64() else {
            eprintln!("  {key:<28} MISSING from BENCH_perf.json");
            failed += 1;
            continue;
        };
        let floor = want * (1.0 - tolerance);
        let ok = got >= floor;
        println!(
            "  {key:<28} {got:>8.2}  (baseline {want:.2}, floor {floor:.2})  {}",
            if ok { "ok" } else { "REGRESSED" }
        );
        if !ok {
            failed += 1;
        }
    }
    // ceilings: rows that regress by GROWING (overhead percentages);
    // optional so older baselines keep working
    if let Some(ceilings) = base.get("ceilings").as_obj() {
        println!(
            "bench guard: {} ceiling(s), fail above baseline + {:.0}%",
            ceilings.len(),
            tolerance * 100.0
        );
        for (key, want) in ceilings {
            let Some(want) = want.as_f64() else {
                eprintln!("  {key:<28} ceiling is not a number — guard misconfigured");
                failed += 1;
                continue;
            };
            let Some(got) = perf.get(key).as_f64() else {
                eprintln!("  {key:<28} MISSING from BENCH_perf.json");
                failed += 1;
                continue;
            };
            let ceiling = want * (1.0 + tolerance);
            let ok = got <= ceiling;
            println!(
                "  {key:<28} {got:>8.2}  (baseline {want:.2}, ceiling {ceiling:.2})  {}",
                if ok { "ok" } else { "REGRESSED" }
            );
            if !ok {
                failed += 1;
            }
        }
    }
    if failed > 0 {
        eprintln!(
            "bench guard: {failed} row(s) regressed past the {:.0}% tolerance",
            tolerance * 100.0
        );
        std::process::exit(1);
    }
    println!("bench guard: all rows within tolerance");
}
