//! Mixed-precision quantization configurations and bit-packing.
//!
//! The paper's central extension to Timeloop: every workload tensor
//! carries a bit-width `(q_a, q_w, q_o)`, and storage levels pack
//! `floor(word_bits / q)` elements into one memory word ("bit-packing",
//! after BitFlow [17]). This shrinks both the *capacity footprint* of a
//! tile (more mappings become valid) and the *word traffic* on every
//! memory interface (less energy).
//!
//! A network-level configuration is the paper's "linear string of tuples
//! of integers": per layer `(q_a, q_w)`, with the output bit-width of
//! layer `i` defined as the input bit-width of layer `i+1` (8 bits for
//! the last layer).

use crate::workload::Tensor;

/// Paper's search range: 2..=8 bits for weights and activations.
pub const QMIN: u8 = 2;
pub const QMAX: u8 = 8;

/// Bit-widths of one layer's three tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerQuant {
    /// Activations (layer inputs).
    pub qa: u8,
    /// Weights.
    pub qw: u8,
    /// Outputs / partial sums as stored to the next level (the paper's
    /// `q_o`; equals the next layer's `q_a`).
    pub qo: u8,
}

impl LayerQuant {
    pub fn uniform(q: u8) -> Self {
        LayerQuant { qa: q, qw: q, qo: q }
    }

    pub fn of(&self, t: Tensor) -> u8 {
        match t {
            Tensor::Weights => self.qw,
            Tensor::Inputs => self.qa,
            Tensor::Outputs => self.qo,
        }
    }

    /// Canonical representative of this quantization's *packing
    /// equivalence class*: the mapping engine observes bit-widths only
    /// through `pack_factor`, so e.g. 6/7/8 bits at a 16-bit word are the
    /// same workload (pack factor 2). Canonicalizing lets the mapper
    /// cache and its RNG seed treat them identically — which also makes
    /// the paper's "no benefit for x >= 6" plateau exact.
    pub fn canonical(&self, word_bits: u32, bit_packing: bool) -> LayerQuant {
        let canon = |q: u8| -> u8 {
            if bit_packing {
                (word_bits as u64 / pack_factor(word_bits, q)) as u8
            } else {
                // without packing only ceil(q/word) matters
                (crate::util::ceil_div(q as u64, word_bits as u64) * word_bits as u64) as u8
            }
        };
        LayerQuant {
            qa: canon(self.qa),
            qw: canon(self.qw),
            qo: canon(self.qo),
        }
    }
}

/// How many data elements of width `q` bits fit in one `word_bits` memory
/// word under bit-packing; without packing this is 1 (one element per
/// word, the "naïve approach" in the paper).
///
/// Elements never straddle words (that is what both BitFlow-style packing
/// and the Timeloop extension assume), so the packing factor is
/// `floor(word_bits / q)`, min 1.
#[inline]
pub fn pack_factor(word_bits: u32, q: u8) -> u64 {
    ((word_bits as u64) / (q as u64).max(1)).max(1)
}

/// Memory words needed for `elements` values of width `q` bits.
#[inline]
pub fn packed_words(elements: u64, word_bits: u32, q: u8) -> u64 {
    crate::util::ceil_div(elements, pack_factor(word_bits, q))
}

/// Words needed without bit-packing (one element per word; elements wider
/// than the word take multiple words).
#[inline]
pub fn unpacked_words(elements: u64, word_bits: u32, q: u8) -> u64 {
    elements * crate::util::ceil_div(q as u64, word_bits as u64)
}

/// A full-network mixed-precision configuration: per layer `(q_a, q_w)`.
///
/// This is the NSGA-II genome. `q_o` is derived, never stored.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    /// (q_a, q_w) per layer.
    pub layers: Vec<(u8, u8)>,
    /// Output bit-width of the final layer (paper: constant 8).
    pub last_qo: u8,
}

impl QuantConfig {
    pub fn uniform(num_layers: usize, q: u8) -> Self {
        QuantConfig {
            layers: vec![(q, q); num_layers],
            last_qo: 8,
        }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Per-layer `(q_a, q_w, q_o)` with the paper's output-chaining rule.
    pub fn layer(&self, i: usize) -> LayerQuant {
        let (qa, qw) = self.layers[i];
        let qo = if i + 1 < self.layers.len() {
            self.layers[i + 1].0
        } else {
            self.last_qo
        };
        LayerQuant { qa, qw, qo }
    }

    /// All layers as resolved `LayerQuant`s.
    pub fn resolved(&self) -> Vec<LayerQuant> {
        (0..self.len()).map(|i| self.layer(i)).collect()
    }

    /// The paper's flat integer-string encoding: `[qa0, qw0, qa1, qw1, ..]`
    /// (56 integers for MobileNetV1).
    pub fn encode(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(self.len() * 2);
        for &(qa, qw) in &self.layers {
            v.push(qa);
            v.push(qw);
        }
        v
    }

    pub fn decode(genome: &[u8], last_qo: u8) -> Result<Self, String> {
        if genome.len() % 2 != 0 {
            return Err(format!("genome length {} is odd", genome.len()));
        }
        for &g in genome {
            if !(QMIN..=QMAX).contains(&g) && g != 16 {
                return Err(format!("bit-width {g} outside 2..=8 (or 16)"));
            }
        }
        Ok(QuantConfig {
            layers: genome.chunks(2).map(|c| (c[0], c[1])).collect(),
            last_qo,
        })
    }

    /// Naïve model size in bits: sum over layers of
    /// `weight_elements * q_w` — the quantity a hardware-unaware method
    /// minimizes (paper Fig. 1 x-axis).
    pub fn model_size_bits(&self, layers: &[crate::workload::ConvLayer]) -> u64 {
        assert_eq!(layers.len(), self.len());
        layers
            .iter()
            .zip(&self.layers)
            .map(|(l, &(_, qw))| l.tensor_elements(Tensor::Weights) * qw as u64)
            .sum()
    }

    /// Weight-memory word count after bit-packing (paper Fig. 1(a) y-axis).
    pub fn weight_memory_words(
        &self,
        layers: &[crate::workload::ConvLayer],
        word_bits: u32,
    ) -> u64 {
        assert_eq!(layers.len(), self.len());
        layers
            .iter()
            .zip(&self.layers)
            .map(|(l, &(_, qw))| packed_words(l.tensor_elements(Tensor::Weights), word_bits, qw))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::mobilenet_v1;

    #[test]
    fn pack_factor_16bit_word() {
        // the paper's observation: for word size 16, no packing benefit
        // change within q in {6,7,8} (factor 2), none at all for q > 8
        assert_eq!(pack_factor(16, 16), 1);
        assert_eq!(pack_factor(16, 9), 1);
        assert_eq!(pack_factor(16, 8), 2);
        assert_eq!(pack_factor(16, 7), 2);
        assert_eq!(pack_factor(16, 6), 2);
        assert_eq!(pack_factor(16, 5), 3);
        assert_eq!(pack_factor(16, 4), 4);
        assert_eq!(pack_factor(16, 3), 5);
        assert_eq!(pack_factor(16, 2), 8);
    }

    #[test]
    fn packed_words_rounding() {
        assert_eq!(packed_words(10, 16, 8), 5);
        assert_eq!(packed_words(11, 16, 8), 6);
        assert_eq!(packed_words(1, 16, 2), 1);
        assert_eq!(packed_words(0, 16, 4), 0);
        // unpacked: one element per word regardless of q <= word
        assert_eq!(unpacked_words(10, 16, 4), 10);
        assert_eq!(unpacked_words(10, 16, 16), 10);
    }

    #[test]
    fn qo_chains_to_next_layers_qa() {
        let mut c = QuantConfig::uniform(3, 8);
        c.layers[1].0 = 4; // layer1 qa = 4
        assert_eq!(c.layer(0).qo, 4);
        assert_eq!(c.layer(1).qo, 8);
        assert_eq!(c.layer(2).qo, 8); // last layer -> last_qo
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut c = QuantConfig::uniform(28, 8);
        c.layers[3] = (2, 5);
        c.layers[27] = (7, 3);
        let g = c.encode();
        assert_eq!(g.len(), 56); // paper: MobileNetV1 string = 56 integers
        let c2 = QuantConfig::decode(&g, 8).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn decode_rejects_bad() {
        assert!(QuantConfig::decode(&[8, 8, 8], 8).is_err());
        assert!(QuantConfig::decode(&[1, 8], 8).is_err());
        assert!(QuantConfig::decode(&[9, 8], 8).is_err());
        assert!(QuantConfig::decode(&[16, 16], 8).is_ok()); // 16-bit baseline allowed
    }

    #[test]
    fn model_size_vs_words_divergence() {
        // the Fig.1 effect in miniature: equal model size, different word
        // count. 5-bit and 4-bit pack differently (3 vs 4 per word).
        let layers = mobilenet_v1();
        let c8 = QuantConfig::uniform(28, 8);
        let c4 = QuantConfig::uniform(28, 4);
        assert_eq!(
            c8.model_size_bits(&layers),
            2 * c4.model_size_bits(&layers)
        );
        assert_eq!(
            c8.weight_memory_words(&layers, 16),
            2 * c4.weight_memory_words(&layers, 16)
        );
        // 6 bits: size = 1.5x of 4-bit, but words = 2x of 4-bit
        let c6 = QuantConfig::uniform(28, 6);
        assert!(c6.model_size_bits(&layers) < c8.model_size_bits(&layers));
        assert_eq!(
            c6.weight_memory_words(&layers, 16),
            c8.weight_memory_words(&layers, 16)
        );
    }
}
