//! GAMMA-style genetic mapper (Kao & Krishna, ICCAD'20 — the paper's
//! reference [8] for "highly optimized dataflow determined using methods
//! such as GAMMA").
//!
//! Instead of random search, a small genetic algorithm evolves mappings
//! of ONE workload: the genome is the mapping itself, crossover swaps
//! whole per-dim factor placements between parents (which preserves the
//! factor-product validity by construction), and mutation re-randomizes
//! one dim's placement or one level's loop permutation. Selection is
//! EDP-tournament with elitism.
//!
//! Used by the `ablation_mapper` bench to quantify what the paper leaves
//! on the table by using Timeloop's random mapper (2000 valid mappings)
//! instead of a guided search at the same evaluation budget.

use super::{EvalContext, MapperResult};
use crate::arch::Arch;
use crate::energy::{estimate_into, Estimate};
use crate::mapping::factorize::random_factorization_into;
use crate::mapping::mapspace::MapSpace;
use crate::mapping::{LayerContext, Mapping};
use crate::nest::analyze_prefilled;
use crate::quant::LayerQuant;
use crate::util::rng::Rng;
use crate::workload::{ConvLayer, DIMS};

/// Genetic-mapper knobs.
#[derive(Debug, Clone, Copy)]
pub struct GammaConfig {
    pub population: usize,
    pub generations: usize,
    /// Tournament size for parent selection.
    pub tournament: usize,
    /// Per-child probability of a dim-placement mutation.
    pub p_mut_dim: f64,
    /// Per-child probability of a permutation mutation.
    pub p_mut_perm: f64,
    /// Elite individuals carried over unchanged per generation.
    pub elites: usize,
    /// Draw budget for seeding the initial population.
    pub init_draws: u64,
    pub seed: u64,
}

impl Default for GammaConfig {
    fn default() -> Self {
        GammaConfig {
            population: 40,
            generations: 50,
            tournament: 3,
            p_mut_dim: 0.35,
            p_mut_perm: 0.25,
            elites: 2,
            init_draws: 50_000,
            seed: 0x6A44A,
        }
    }
}

impl GammaConfig {
    /// Total mapping evaluations this config spends (for budget-matched
    /// comparisons against the random mapper).
    pub fn budget(&self) -> u64 {
        (self.population * (self.generations + 1)) as u64
    }
}

struct Scored {
    mapping: Mapping,
    est: Option<Estimate>,
    edp: f64,
}

/// Copy dim `d`'s temporal + spatial placement from `src` into `dst`.
fn copy_dim(dst: &mut Mapping, src: &Mapping, d: usize) {
    for lv in 0..dst.levels.len() {
        dst.levels[lv].temporal[d] = src.levels[lv].temporal[d];
        dst.levels[lv].spatial[d] = src.levels[lv].spatial[d];
    }
}

/// Re-randomize dim `d`'s placement using the mapspace sampler
/// (allocation-free: primes come from the layer context).
fn randomize_dim(
    space: &MapSpace,
    lctx: &LayerContext,
    m: &mut Mapping,
    d: usize,
    fbuf: &mut [u64],
    rng: &mut Rng,
) {
    random_factorization_into(&lctx.dim_primes[d], rng, fbuf);
    for lv in 0..space.num_levels {
        m.levels[lv].temporal[d] = fbuf[lv];
    }
    for (si, &lv) in space.spatial_levels.iter().enumerate() {
        m.levels[lv].spatial[d] = fbuf[space.num_levels + si];
    }
}

/// Check + price one candidate through the staged cascade the random
/// mapper uses: spatial pre-check, then extent/capacity check recording
/// tile footprints, then prefilled analysis — verdict- and
/// price-identical to `check` + `analyze_into`, without recomputing any
/// tile size for a valid candidate.
fn score(lctx: &LayerContext, ectx: &mut EvalContext, m: &Mapping) -> Scored {
    if lctx.check_spatial(m).is_err()
        || lctx.check_tiles_into(m, &mut ectx.ext, &mut ectx.elems).is_err()
    {
        return Scored {
            mapping: m.clone(),
            est: None,
            edp: f64::INFINITY,
        };
    }
    analyze_prefilled(lctx, m, &ectx.elems, &mut ectx.nest);
    estimate_into(lctx, &ectx.nest, &mut ectx.est);
    Scored {
        mapping: m.clone(),
        edp: ectx.est.edp(),
        est: Some(ectx.est.clone()),
    }
}

/// Run the genetic mapper on one workload. Returns the same result type
/// as [`super::search`] so callers can swap mappers freely.
pub fn search(arch: &Arch, layer: &ConvLayer, q: &LayerQuant, cfg: &GammaConfig) -> MapperResult {
    let q = &q.canonical(arch.word_bits, arch.bit_packing);
    let space = MapSpace::of(arch);
    let lctx = LayerContext::new(arch, layer, q);
    let mut ectx = EvalContext::for_arch(arch);
    let mut rng = Rng::new(cfg.seed ^ super::workload_hash(layer, q));

    // ---- seed: random valid mappings (fall back to invalid-tolerant
    // fill if validity is rare, so the GA can still repair them)
    let mut pop: Vec<Scored> = Vec::with_capacity(cfg.population);
    let mut draws = 0u64;
    while pop.len() < cfg.population && draws < cfg.init_draws {
        draws += 1;
        space.random_mapping_into(&lctx, &mut rng, &mut ectx.fbuf, &mut ectx.mapping);
        if lctx.check(&ectx.mapping, &mut ectx.ext).is_ok() {
            let m = ectx.mapping.clone();
            pop.push(score(&lctx, &mut ectx, &m));
        }
    }
    while pop.len() < cfg.population {
        // mapspace too hostile for random validity: admit invalid seeds
        space.random_mapping_into(&lctx, &mut rng, &mut ectx.fbuf, &mut ectx.mapping);
        let m = ectx.mapping.clone();
        pop.push(score(&lctx, &mut ectx, &m));
    }
    let mut evals = pop.len() as u64;
    let mut valid = pop.iter().filter(|s| s.est.is_some()).count() as u64;

    // ---- evolve
    for _gen in 0..cfg.generations {
        pop.sort_by(|a, b| a.edp.partial_cmp(&b.edp).unwrap());
        let mut next: Vec<Scored> = Vec::with_capacity(cfg.population);
        for e in pop.iter().take(cfg.elites) {
            next.push(Scored {
                mapping: e.mapping.clone(),
                est: e.est.clone(),
                edp: e.edp,
            });
        }
        let tourney = |rng: &mut Rng, pop: &[Scored]| -> usize {
            let mut best = rng.range(0, pop.len() - 1);
            for _ in 1..cfg.tournament {
                let c = rng.range(0, pop.len() - 1);
                if pop[c].edp < pop[best].edp {
                    best = c;
                }
            }
            best
        };
        while next.len() < cfg.population {
            let pa = tourney(&mut rng, &pop);
            let pb = tourney(&mut rng, &pop);
            // per-dim uniform crossover: child takes each dim's whole
            // placement from one parent -> factor products stay exact
            let mut child = pop[pa].mapping.clone();
            for d in 0..DIMS.len() {
                if rng.chance(0.5) {
                    copy_dim(&mut child, &pop[pb].mapping, d);
                }
            }
            if rng.chance(cfg.p_mut_dim) {
                let d = rng.range(0, DIMS.len() - 1);
                randomize_dim(&space, &lctx, &mut child, d, &mut ectx.fbuf, &mut rng);
            }
            if rng.chance(cfg.p_mut_perm) {
                let lv = rng.range(0, child.levels.len() - 1);
                let mut perm = child.levels[lv].perm;
                rng.shuffle(&mut perm);
                child.levels[lv].perm = perm;
            }
            let s = score(&lctx, &mut ectx, &child);
            evals += 1;
            if s.est.is_some() {
                valid += 1;
            }
            next.push(s);
        }
        pop = next;
    }

    pop.sort_by(|a, b| a.edp.partial_cmp(&b.edp).unwrap());
    let best = pop.into_iter().next().filter(|s| s.est.is_some());
    match best {
        Some(s) => MapperResult {
            best: s.est,
            best_mapping: Some(s.mapping),
            valid,
            draws: evals,
        },
        None => MapperResult {
            best: None,
            best_mapping: None,
            valid: 0,
            draws: evals,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{eyeriss, toy};
    use crate::mapper::MapperConfig;
    use crate::mapping::check;

    fn small_cfg() -> GammaConfig {
        GammaConfig {
            population: 16,
            generations: 12,
            init_draws: 20_000,
            ..GammaConfig::default()
        }
    }

    #[test]
    fn finds_valid_mapping_on_toy() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let r = search(&a, &l, &LayerQuant::uniform(8), &small_cfg());
        let est = r.best.expect("gamma must find a valid mapping");
        assert!(est.edp() > 0.0);
        // the returned mapping must itself be valid
        let m = r.best_mapping.unwrap();
        check(&a, &l, &LayerQuant::uniform(8), &m).unwrap();
    }

    #[test]
    fn deterministic_given_seed() {
        let a = toy();
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 1);
        let q = LayerQuant::uniform(4);
        let r1 = search(&a, &l, &q, &small_cfg());
        let r2 = search(&a, &l, &q, &small_cfg());
        assert_eq!(
            r1.best.map(|e| e.edp().to_bits()),
            r2.best.map(|e| e.edp().to_bits())
        );
    }

    #[test]
    fn beats_or_matches_random_at_equal_budget() {
        // the GAMMA pitch: guided search >= random search per evaluation
        let a = eyeriss();
        let l = ConvLayer::pw("pw", 64, 128, 14);
        let q = LayerQuant::uniform(8);
        let g = GammaConfig {
            population: 30,
            generations: 20,
            ..GammaConfig::default()
        };
        let budget = g.budget();
        let rnd = crate::mapper::search(
            &a,
            &l,
            &q,
            &MapperConfig {
                valid_target: budget,
                max_draws: budget * 50,
                seed: 9,
                shards: 1,
            },
        );
        let gam = search(&a, &l, &q, &g);
        let e_rnd = rnd.best.expect("random finds something").edp();
        let e_gam = gam.best.expect("gamma finds something").edp();
        // allow a little slack: equal-budget GA should be at least close
        assert!(
            e_gam <= e_rnd * 1.10,
            "gamma {e_gam:.3e} much worse than random {e_rnd:.3e}"
        );
    }

    #[test]
    fn crossover_preserves_products() {
        let a = toy();
        let space = MapSpace::of(&a);
        let l = ConvLayer::conv("t", 4, 8, 3, 8, 2);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let p1 = space.random_mapping(&l, &mut rng);
            let p2 = space.random_mapping(&l, &mut rng);
            let mut child = p1.clone();
            for d in 0..7 {
                if rng.chance(0.5) {
                    copy_dim(&mut child, &p2, d);
                }
            }
            let tot = child.total_extents();
            for d in crate::workload::DIMS {
                assert_eq!(tot[d.index()], l.size(d));
            }
        }
    }
}
