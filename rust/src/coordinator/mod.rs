//! L3 coordinator: experiment drivers shared by the CLI (`qmap <cmd>`)
//! and the `benches/` harnesses that regenerate every paper table and
//! figure. Each function returns structured rows; formatting lives in
//! `crate::report` and the callers.

pub mod experiments;

use crate::mapper::MapperConfig;
use crate::nsga::NsgaConfig;

/// Global experiment knobs with paper-faithful defaults, scaled for a
/// laptop-class run (DESIGN.md §3: budget substitution).
#[derive(Debug, Clone, Copy)]
pub struct RunConfig {
    pub mapper: MapperConfig,
    pub nsga: NsgaConfig,
    /// Worker threads for parallel candidate evaluation.
    pub threads: usize,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            mapper: MapperConfig::default(),
            nsga: NsgaConfig::default(),
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            seed: 0x9A9E12,
        }
    }
}

impl RunConfig {
    /// Profile selection for the bench harnesses: `QMAP_PROFILE` =
    /// `fast` (CI smoke) | `default` | `full` (paper-faithful budgets),
    /// with `QMAP_THREADS` / `QMAP_SEED` overrides.
    pub fn from_env() -> Self {
        let mut rc = match std::env::var("QMAP_PROFILE").as_deref() {
            Ok("fast") => RunConfig::fast(),
            Ok("full") => RunConfig::full(),
            _ => RunConfig::default(),
        };
        if let Ok(t) = std::env::var("QMAP_THREADS") {
            if let Ok(t) = t.parse() {
                rc.threads = t;
            }
        }
        if let Ok(s) = std::env::var("QMAP_SEED") {
            if let Ok(s) = s.parse() {
                rc.seed = s;
            }
        }
        if let Ok(s) = std::env::var("QMAP_SHARDS") {
            if let Ok(s) = s.parse() {
                rc.mapper.shards = s;
            }
        }
        rc
    }

    /// Paper-faithful budgets (2000 valid mappings per workload,
    /// |P|=32, |Q|=16, 20 generations) — minutes-scale on a laptop.
    pub fn full() -> Self {
        RunConfig {
            mapper: MapperConfig {
                valid_target: 2_000,
                max_draws: 2_000_000,
                seed: 7,
                // population-level parallelism already saturates the
                // cores; per-workload sharding stays off by default
                shards: 1,
            },
            nsga: NsgaConfig::default(),
            ..RunConfig::default()
        }
    }

    /// A fast profile for tests and smoke runs.
    pub fn fast() -> Self {
        RunConfig {
            mapper: MapperConfig {
                valid_target: 60,
                max_draws: 60_000,
                seed: 1,
                shards: 1,
            },
            nsga: NsgaConfig {
                population: 12,
                offspring: 8,
                generations: 6,
                ..NsgaConfig::default()
            },
            threads: 4,
            seed: 1,
        }
    }
}
