//! k-objective correctness of the NSGA-II internals and the typed
//! objective space — unit + property tests.
//!
//! The tentpole refactor made the objective arity a run-time property
//! of the `ObjectiveSpec` instead of a hardcoded 2, so `dominates`,
//! `non_dominated_sort`, crowding distance, environmental selection,
//! and the front utilities must be *provably* k-objective-correct and
//! deterministic — including 3- and 4-axis vectors, duplicate points,
//! infinite crowding at front extremes, and permutation independence
//! (the property the distributed bit-identity guarantees stand on).

use qmap::nsga::{
    crowding_distance, dominates, environmental_select, non_dominated_sort,
    pareto_front_of_points, Individual,
};
use qmap::objective::{Axis, ObjectiveSpec, ObjectiveVec};
use qmap::quant::QuantConfig;
use qmap::util::prop::check;
use qmap::util::rng::Rng;

fn ind(objs: Vec<f64>) -> Individual {
    Individual {
        genome: QuantConfig::uniform(2, 8),
        objectives: ObjectiveVec::raw(objs),
    }
}

/// A random population of k-objective points on a small integer grid
/// (small coordinates force plenty of ties and duplicates — the cases
/// the two-objective era never exercised).
fn random_points(r: &mut Rng, k: usize) -> Vec<Vec<f64>> {
    let n = r.range(2, 24);
    (0..n)
        .map(|_| (0..k).map(|_| r.below(4) as f64).collect())
        .collect()
}

// ------------------------------------------------------------ dominance

/// The textbook definition, written independently of the
/// implementation: all <= and at least one <.
fn dominates_naive(a: &[f64], b: &[f64]) -> bool {
    a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
}

#[test]
fn dominance_matches_the_definition_for_3_and_4_axes() {
    for k in [3usize, 4] {
        check(
            0x0B31 ^ k as u64,
            400,
            |r| random_points(r, k),
            |pts| {
                for a in pts {
                    for b in pts {
                        if dominates(a, b) != dominates_naive(a, b) {
                            return Err(format!("dominates({a:?}, {b:?}) disagrees"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn dominance_axioms_hold_with_duplicates_and_infinities() {
    // equal vectors never dominate (duplicates are mutually
    // non-dominated), dominance is irreflexive and asymmetric, and an
    // unmappable genome's +inf hardware axes lose to any finite value
    let a = vec![1.0, 2.0, 3.0];
    assert!(!dominates(&a, &a));
    let worse = vec![1.0, 2.0, f64::INFINITY];
    assert!(dominates(&a, &worse));
    assert!(!dominates(&worse, &a));
    let inf2 = vec![f64::INFINITY, 2.0, 3.0];
    // incomparable: each wins one axis
    assert!(!dominates(&worse, &inf2) && !dominates(&inf2, &worse));
}

#[test]
fn non_dominated_sort_fronts_are_sound_for_k_axes() {
    for k in [2usize, 3, 4] {
        check(
            0x50B7 ^ k as u64,
            200,
            |r| random_points(r, k),
            |pts| {
                let pop: Vec<Individual> = pts.iter().map(|p| ind(p.clone())).collect();
                let fronts = non_dominated_sort(&pop);
                // partition: every index appears exactly once
                let mut seen = vec![false; pop.len()];
                for f in &fronts {
                    for &i in f {
                        if seen[i] {
                            return Err(format!("index {i} in two fronts"));
                        }
                        seen[i] = true;
                    }
                }
                if !seen.iter().all(|&s| s) {
                    return Err("sort dropped an individual".into());
                }
                // within a front: mutually non-dominated; and every
                // member of front j>0 is dominated by someone in j-1
                for (j, f) in fronts.iter().enumerate() {
                    for &i1 in f {
                        for &i2 in f {
                            if dominates(&pop[i1].objectives, &pop[i2].objectives) {
                                return Err(format!("front {j} not mutually non-dominated"));
                            }
                        }
                        if j > 0
                            && !fronts[j - 1].iter().any(|&p| {
                                dominates(&pop[p].objectives, &pop[i1].objectives)
                            })
                        {
                            return Err(format!(
                                "front {j} member {i1} not dominated by front {}",
                                j - 1
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

// ------------------------------------------------------------- crowding

#[test]
fn crowding_extremes_are_infinite_on_every_axis_for_k_objectives() {
    // a 3-axis front where each axis has a distinct extreme point:
    // each extreme must pick up an infinite distance
    let pop = vec![
        ind(vec![0.0, 5.0, 5.0]),
        ind(vec![5.0, 0.0, 5.0]),
        ind(vec![5.0, 5.0, 0.0]),
        ind(vec![2.0, 2.0, 2.0]), // interior on no axis extreme... but
                                  // it IS non-extreme on all: finite
    ];
    // (all four are mutually non-dominated)
    let front: Vec<usize> = (0..pop.len()).collect();
    let d = crowding_distance(&pop, &front);
    assert!(d[0].is_infinite() && d[1].is_infinite() && d[2].is_infinite());
    assert!(d[3].is_finite());
}

#[test]
fn crowding_handles_duplicate_points_without_nan() {
    let pop = vec![
        ind(vec![1.0, 2.0, 3.0]),
        ind(vec![1.0, 2.0, 3.0]), // exact duplicate
        ind(vec![3.0, 1.0, 2.0]),
        ind(vec![2.0, 3.0, 1.0]),
    ];
    let front: Vec<usize> = (0..pop.len()).collect();
    let d = crowding_distance(&pop, &front);
    assert!(d.iter().all(|x| !x.is_nan()), "{d:?}");
    // a fully degenerate front (all identical) is all zeros, not NaN
    let dup = vec![ind(vec![1.0, 1.0, 1.0]); 3];
    let d = crowding_distance(&dup, &[0, 1, 2]);
    assert!(d.iter().all(|x| !x.is_nan()), "{d:?}");
}

/// Exact bit key of one distance value (infinities included).
fn dist_bits(x: f64) -> u64 {
    x.to_bits()
}

#[test]
fn crowding_is_permutation_deterministic_for_3_and_4_axes() {
    // the distance belongs to the point's objective VECTOR, not to its
    // position in the front: permuting the front index order must
    // permute the distances with it for every point whose vector is
    // unique, and preserve the (vector, distance) multiset overall
    // (exact duplicates are indistinguishable by value, so only their
    // copies may trade places). This is the determinism k-objective
    // selection — and therefore the serial-vs-distributed bit-identity
    // — rests on; ties on single axes are the norm on a small grid.
    for k in [3usize, 4] {
        check(
            0xC04D ^ k as u64,
            200,
            |r| {
                let pts = random_points(r, k);
                let mut perm: Vec<usize> = (0..pts.len()).collect();
                r.shuffle(&mut perm);
                (pts, perm)
            },
            |(pts, perm)| {
                let pop: Vec<Individual> = pts.iter().map(|p| ind(p.clone())).collect();
                let front: Vec<usize> = (0..pop.len()).collect();
                let base = crowding_distance(&pop, &front);
                let permuted = crowding_distance(&pop, perm);
                // per-point equality (bitwise) for unique vectors
                for (slot, &orig_idx) in perm.iter().enumerate() {
                    let unique = pts
                        .iter()
                        .enumerate()
                        .filter(|(j, p)| *j != orig_idx && **p == pts[orig_idx])
                        .count()
                        == 0;
                    if unique && dist_bits(permuted[slot]) != dist_bits(base[orig_idx]) {
                        return Err(format!(
                            "distance of unique point {orig_idx} changed under \
                             permutation: {} -> {} (k={k})",
                            base[orig_idx], permuted[slot]
                        ));
                    }
                }
                // multiset of (vector, distance) preserved exactly
                let mut m1: Vec<(Vec<u64>, u64)> = front
                    .iter()
                    .map(|&i| {
                        (pts[i].iter().map(|x| x.to_bits()).collect(), dist_bits(base[i]))
                    })
                    .collect();
                let mut m2: Vec<(Vec<u64>, u64)> = perm
                    .iter()
                    .enumerate()
                    .map(|(slot, &i)| {
                        (
                            pts[i].iter().map(|x| x.to_bits()).collect(),
                            dist_bits(permuted[slot]),
                        )
                    })
                    .collect();
                m1.sort();
                m2.sort();
                if m1 != m2 {
                    return Err(format!(
                        "(vector, distance) multiset changed under permutation (k={k})"
                    ));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn environmental_selection_is_input_order_deterministic() {
    // the same multiset of individuals in the same order always
    // selects the same survivors (stable sorts end to end) — run the
    // selection twice and compare exactly
    check(
        0x5E1E,
        150,
        |r| random_points(r, 3),
        |pts| {
            let pop1: Vec<Individual> = pts.iter().map(|p| ind(p.clone())).collect();
            let pop2 = pop1.clone();
            let keep = (pts.len() / 2).max(1);
            let s1: Vec<Vec<f64>> = environmental_select(pop1, keep)
                .into_iter()
                .map(|i| i.objectives.values().to_vec())
                .collect();
            let s2: Vec<Vec<f64>> = environmental_select(pop2, keep)
                .into_iter()
                .map(|i| i.objectives.values().to_vec())
                .collect();
            if s1 != s2 {
                return Err(format!("selection not deterministic: {s1:?} vs {s2:?}"));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------ front utilities

#[test]
fn pareto_front_of_points_is_permutation_invariant_including_order() {
    // the satellite fix: equal-first-axis points used to keep input
    // order; now the output (content AND order) is a pure function of
    // the point set, for any arity
    for k in [2usize, 3, 4] {
        check(
            0xFA0B ^ k as u64,
            200,
            |r| {
                let pts = random_points(r, k);
                let mut shuffled = pts.clone();
                r.shuffle(&mut shuffled);
                (pts, shuffled)
            },
            |(pts, shuffled)| {
                let f1 = pareto_front_of_points(pts);
                let f2 = pareto_front_of_points(shuffled);
                if f1 != f2 {
                    return Err(format!(
                        "front depends on input order (k={k}):\n{f1:?}\nvs\n{f2:?}"
                    ));
                }
                // soundness: nothing in the front is dominated
                for a in &f1 {
                    if pts.iter().any(|q| dominates(q, a)) {
                        return Err(format!("dominated point {a:?} in front"));
                    }
                }
                Ok(())
            },
        );
    }
}

// ----------------------------------------------- spec-driven evaluation

#[test]
fn spec_evaluation_prices_a_real_network_consistently() {
    // one real characterization, every axis checked against its
    // NetworkEval field — the single evaluation site does what the
    // deleted inline computations did
    let arch = qmap::arch::presets::toy();
    let layers = vec![
        qmap::workload::ConvLayer::conv("c1", 3, 8, 3, 16, 1),
        qmap::workload::ConvLayer::fc("fc", 16, 10),
    ];
    let qc = QuantConfig::uniform(layers.len(), 8);
    let cache = qmap::mapper::cache::MapperCache::new();
    let cfg = qmap::mapper::MapperConfig {
        valid_target: 30,
        max_draws: 30_000,
        seed: 3,
        shards: 1,
    };
    let hw = qmap::eval::evaluate_network(&arch, &layers, &qc, &cache, &cfg).unwrap();
    let spec = ObjectiveSpec::new(&Axis::ALL).unwrap();
    let v = spec.evaluate(Some(&hw), 0.9);
    assert_eq!(v[spec.index_of(Axis::Error).unwrap()], 1.0 - 0.9);
    assert_eq!(v[spec.index_of(Axis::Energy).unwrap()].to_bits(), hw.energy_pj.to_bits());
    assert_eq!(
        v[spec.index_of(Axis::MemoryEnergy).unwrap()].to_bits(),
        hw.memory_energy_pj.to_bits()
    );
    assert_eq!(v[spec.index_of(Axis::Edp).unwrap()].to_bits(), hw.edp.to_bits());
    assert_eq!(v[spec.index_of(Axis::Cycles).unwrap()].to_bits(), hw.cycles.to_bits());
    assert_eq!(v[spec.index_of(Axis::WeightWords).unwrap()], hw.weight_words as f64);
    assert_eq!(v[spec.index_of(Axis::ModelSize).unwrap()], hw.model_size_bits as f64);
    // unmappable: hardware axes infinite, error intact
    let dead = spec.evaluate(None, 0.4);
    for (i, axis) in spec.axes().iter().enumerate() {
        if *axis == Axis::Error {
            assert_eq!(dead[i], 1.0 - 0.4);
        } else {
            assert!(dead[i].is_infinite(), "{axis:?}");
        }
    }
}

#[test]
fn three_objective_search_produces_a_mutually_nondominated_front() {
    // a small end-to-end 3-objective search on the toy accelerator:
    // every returned candidate must be non-dominated under the chosen
    // axes — the acceptance property the 2-objective era asserted only
    // for (edp, error)
    let arch = qmap::arch::presets::toy();
    let layers = vec![
        qmap::workload::ConvLayer::conv("c1", 3, 8, 3, 16, 1),
        qmap::workload::ConvLayer::dw("d1", 8, 3, 16, 1),
        qmap::workload::ConvLayer::pw("p1", 8, 16, 16),
        qmap::workload::ConvLayer::fc("fc", 16, 10),
    ];
    let spec = ObjectiveSpec::parse("error,energy,weight_words").unwrap();
    let engine = qmap::engine::Engine::new(2).with_objectives(spec);
    let cache = qmap::mapper::cache::MapperCache::new();
    let map_cfg = qmap::mapper::MapperConfig {
        valid_target: 24,
        max_draws: 24_000,
        seed: 7,
        shards: 1,
    };
    let nsga_cfg = qmap::nsga::NsgaConfig {
        population: 8,
        offspring: 4,
        generations: 3,
        seed: 11,
        ..qmap::nsga::NsgaConfig::default()
    };
    let mut acc = qmap::accuracy::ProxyAccuracy::new(
        &layers,
        qmap::accuracy::ProxyParams::default(),
    );
    let cands = qmap::baselines::search_with_objectives(
        &engine, &arch, &layers, &mut acc, &cache, &map_cfg, &nsga_cfg, &spec, |_, _| {},
    );
    assert!(!cands.is_empty());
    let pts: Vec<Vec<f64>> = cands
        .iter()
        .map(|c| spec.evaluate(Some(&c.hw), c.accuracy).into_values())
        .collect();
    for (i, a) in pts.iter().enumerate() {
        assert_eq!(a.len(), 3);
        for b in &pts {
            assert!(
                !dominates(b, a) || b == a,
                "candidate {i} dominated under {spec}: {a:?} by {b:?}"
            );
        }
    }
}
