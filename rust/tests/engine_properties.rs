//! Property-based tests over the mapping engine's invariants
//! (DESIGN.md deliverable (c): proptest-style coverage on the L3
//! coordinator state — here, the mapping/quantization/energy substrate
//! every experiment rests on).

use qmap::arch::presets::{eyeriss, simba, toy};
use qmap::arch::Arch;
use qmap::mapping::mapspace::MapSpace;
use qmap::mapping::{check, tile_words, Violation};
use qmap::nest;
use qmap::quant::{pack_factor, packed_words, unpacked_words, LayerQuant, QuantConfig, QMAX, QMIN};
use qmap::util::prop::check as forall;
use qmap::util::rng::Rng;
use qmap::workload::{ConvLayer, Tensor, TENSORS};

/// Random layer generator: plausible CNN layer geometries, including
/// depthwise, pointwise and strided shapes.
fn random_layer(r: &mut Rng) -> ConvLayer {
    let c = [1u64, 3, 4, 8, 16, 32][r.range(0, 5)];
    let k = [4u64, 8, 16, 32][r.range(0, 3)];
    let p = [4u64, 7, 8, 14, 16, 28][r.range(0, 5)];
    let stride = [1u64, 2][r.range(0, 1)];
    match r.range(0, 3) {
        0 => ConvLayer::conv("prop_conv", c, k, 3, p, stride),
        1 => ConvLayer::dw("prop_dw", c.max(2), 3, p, stride),
        2 => ConvLayer::pw("prop_pw", c, k, p),
        _ => ConvLayer::fc("prop_fc", c * 16, k),
    }
}

fn random_quant(r: &mut Rng) -> LayerQuant {
    LayerQuant {
        qa: QMIN + r.below((QMAX - QMIN + 1) as u64) as u8,
        qw: QMIN + r.below((QMAX - QMIN + 1) as u64) as u8,
        qo: QMIN + r.below((QMAX - QMIN + 1) as u64) as u8,
    }
}

fn random_arch(r: &mut Rng) -> Arch {
    [toy(), eyeriss(), simba()][r.range(0, 2)].clone()
}

// ---------------------------------------------------------------- packing

#[test]
fn packing_never_exceeds_unpacked() {
    forall(
        0xBAC4,
        2000,
        |r| (r.below(1 << 20) + 1, 1 + r.below(64) as u32, 1 + r.below(16) as u8),
        |&(elems, word_bits, q)| {
            if u32::from(q) > word_bits {
                return Ok(()); // element wider than word: packing undefined
            }
            let p = packed_words(elems, word_bits, q);
            let u = unpacked_words(elems, word_bits, q);
            if p > u {
                return Err(format!("packed {p} > unpacked {u}"));
            }
            // ceil-division identity: p == ceil(elems / floor(word/q))
            let f = pack_factor(word_bits, q);
            if p != elems.div_ceil(f) {
                return Err(format!("p={p} != ceil({elems}/{f})"));
            }
            Ok(())
        },
    );
}

#[test]
fn packed_words_monotone_in_bits() {
    forall(
        0xBAC5,
        1000,
        |r| (r.below(1 << 18) + 1, r.below(7) as u8 + 2),
        |&(elems, q)| {
            // at fixed word size 16, fewer bits can never need more words
            let lo = packed_words(elems, 16, q);
            let hi = packed_words(elems, 16, q + 1);
            if lo > hi {
                return Err(format!("q={q}: {lo} words > q+1: {hi}"));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ genome codec

#[test]
fn genome_encode_decode_roundtrip() {
    forall(
        0x6E0,
        500,
        |r| {
            let n = r.range(1, 60);
            let mut qc = QuantConfig::uniform(n, 8);
            for l in qc.layers.iter_mut() {
                l.0 = QMIN + r.below((QMAX - QMIN + 1) as u64) as u8;
                l.1 = QMIN + r.below((QMAX - QMIN + 1) as u64) as u8;
            }
            qc
        },
        |qc| {
            let bytes = qc.encode();
            let back = QuantConfig::decode(&bytes, 8).map_err(|e| e.to_string())?;
            if back.layers != qc.layers {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn resolved_qo_is_next_layers_qa() {
    forall(
        0x6E1,
        300,
        |r| {
            let n = r.range(2, 30);
            let mut qc = QuantConfig::uniform(n, 8);
            for l in qc.layers.iter_mut() {
                l.0 = QMIN + r.below(7) as u8;
            }
            qc
        },
        |qc| {
            let rs = qc.resolved();
            for i in 0..rs.len() - 1 {
                if rs[i].qo != qc.layers[i + 1].0 {
                    return Err(format!("layer {i}: qo {} != next qa {}", rs[i].qo, qc.layers[i + 1].0));
                }
            }
            // paper: "constant 8 bits are set for the last layer's outputs"
            if rs.last().unwrap().qo != 8 {
                return Err("last qo must be 8".into());
            }
            Ok(())
        },
    );
}

// --------------------------------------------------------- mapping validity

#[test]
fn valid_mappings_respect_capacities() {
    forall(
        0xA11D,
        250,
        |r| {
            let arch = random_arch(r);
            let layer = random_layer(r);
            let q = random_quant(r);
            let seed = r.next_u64();
            (arch, layer, q, seed)
        },
        |(arch, layer, q, seed)| {
            let space = MapSpace::of(arch);
            let mut rng = Rng::new(*seed);
            for _ in 0..200 {
                let m = space.random_mapping(layer, &mut rng);
                if check(arch, layer, q, &m).is_err() {
                    continue;
                }
                // every kept tile must fit (in packed words)
                for lv in 0..arch.levels.len() - 1 {
                    for t in TENSORS {
                        if !arch.levels[lv].keeps_tensor(t) {
                            continue;
                        }
                        let w = tile_words(arch, layer, &m, lv, t, q);
                        if let Some(cap) = arch.levels[lv].capacity_for(t) {
                            if matches!(arch.levels[lv].capacity, qmap::arch::Capacity::PerTensor(_))
                                && w > cap
                            {
                                return Err(format!(
                                    "level {lv} tensor {t:?}: {w} words > cap {cap}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn check_rejects_wrong_factor_products() {
    forall(
        0xA11E,
        200,
        |r| (random_layer(r), r.next_u64()),
        |(layer, seed)| {
            let arch = toy();
            let space = MapSpace::of(&arch);
            let mut rng = Rng::new(*seed);
            let mut m = space.random_mapping(layer, &mut rng);
            // corrupt one factor so the product no longer matches
            m.levels[0].temporal[0] += 1;
            match check(&arch, layer, &LayerQuant::uniform(8), &m) {
                Err(Violation::FactorProduct(_)) => Ok(()),
                Err(_) => Ok(()), // a different violation may trigger first
                Ok(()) => Err("corrupted mapping accepted".into()),
            }
        },
    );
}

#[test]
fn lower_bits_admit_supersets_of_mappings() {
    // THE paper invariant: any mapping valid at q is valid at q' <= q
    // (bit-packing only shrinks footprints).
    forall(
        0x5B5,
        150,
        |r| {
            let layer = random_layer(r);
            let q = random_quant(r);
            let seed = r.next_u64();
            (layer, q, seed)
        },
        |(layer, q, seed)| {
            let arch = eyeriss();
            let space = MapSpace::of(&arch);
            let mut rng = Rng::new(*seed);
            let smaller = LayerQuant {
                qa: QMIN.max(q.qa - 1),
                qw: QMIN.max(q.qw - 1),
                qo: QMIN.max(q.qo - 1),
            };
            for _ in 0..100 {
                let m = space.random_mapping(layer, &mut rng);
                if check(&arch, layer, q, &m).is_ok()
                    && check(&arch, layer, &smaller, &m).is_err()
                {
                    return Err(format!(
                        "mapping valid at {q:?} but invalid at {smaller:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------ nest analysis

#[test]
fn nest_macs_match_workload() {
    forall(
        0x4E57,
        200,
        |r| {
            let arch = random_arch(r);
            let layer = random_layer(r);
            let seed = r.next_u64();
            (arch, layer, seed)
        },
        |(arch, layer, seed)| {
            let space = MapSpace::of(arch);
            let mut rng = Rng::new(*seed);
            let q = LayerQuant::uniform(8);
            for _ in 0..100 {
                let m = space.random_mapping(layer, &mut rng);
                if check(arch, layer, &q, &m).is_err() {
                    continue;
                }
                let nest = nest::analyze(arch, layer, &m);
                if nest.macs != layer.macs() {
                    return Err(format!(
                        "nest macs {} != workload macs {}",
                        nest.macs,
                        layer.macs()
                    ));
                }
                if nest.pes_used == 0 || nest.pes_used > arch.total_pes() {
                    return Err(format!("pes_used {} out of range", nest.pes_used));
                }
                // every level's traffic must be non-negative and finite
                for la in &nest.accesses {
                    for t in &la[..] {
                        if !(t.reads.is_finite() && t.writes.is_finite())
                            || t.reads < 0.0
                            || t.writes < 0.0
                        {
                            return Err("non-finite or negative traffic".into());
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dram_reads_cover_each_tensor_at_least_once() {
    // every weight/input element must enter the chip at least once; the
    // DRAM read count can exceed the tensor size (re-fetch) but never
    // undercut it.
    forall(
        0x4E58,
        150,
        |r| (random_layer(r), r.next_u64()),
        |(layer, seed)| {
            let arch = eyeriss();
            let space = MapSpace::of(&arch);
            let mut rng = Rng::new(*seed);
            let q = LayerQuant::uniform(8);
            let dram = arch.levels.len() - 1;
            for _ in 0..60 {
                let m = space.random_mapping(layer, &mut rng);
                if check(&arch, layer, &q, &m).is_err() {
                    continue;
                }
                let nest = nest::analyze(&arch, layer, &m);
                for t in [Tensor::Weights, Tensor::Inputs] {
                    let reads = nest.accesses[dram][t.index()].reads;
                    let elems = layer.tensor_elements(t) as f64;
                    if reads + 1e-6 < elems {
                        return Err(format!(
                            "{t:?}: DRAM reads {reads} < tensor elements {elems}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ----------------------------------------------------------------- energy

#[test]
fn energy_monotone_in_bitwidth_for_fixed_mapping() {
    // for one fixed valid mapping, pricing it at fewer bits can never
    // cost more memory energy (same accesses, fewer words per access)
    forall(
        0xE4E,
        150,
        |r| (random_layer(r), r.next_u64()),
        |(layer, seed)| {
            let arch = eyeriss();
            let space = MapSpace::of(&arch);
            let mut rng = Rng::new(*seed);
            let q8 = LayerQuant::uniform(8);
            for _ in 0..60 {
                let m = space.random_mapping(layer, &mut rng);
                if check(&arch, layer, &q8, &m).is_err() {
                    continue;
                }
                let nest = nest::analyze(&arch, layer, &m);
                let e8 = qmap::energy::estimate(&arch, layer, &q8, &nest);
                let e2 = qmap::energy::estimate(&arch, layer, &LayerQuant::uniform(2), &nest);
                if e2.memory_energy_pj() > e8.memory_energy_pj() + 1e-9 {
                    return Err(format!(
                        "memory energy grew: 2b {} > 8b {}",
                        e2.memory_energy_pj(),
                        e8.memory_energy_pj()
                    ));
                }
                if (e2.mac_energy_pj - e8.mac_energy_pj).abs() > 1e-9 {
                    return Err("MAC energy must not depend on bits".into());
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------------------ NSGA-II

#[test]
fn pareto_front_has_no_dominated_points() {
    forall(
        0x9A12,
        400,
        |r| {
            let n = r.range(2, 40);
            (0..n)
                .map(|_| vec![r.f64(), r.f64()])
                .collect::<Vec<Vec<f64>>>()
        },
        |pts| {
            let front = qmap::nsga::pareto_front_of_points(pts);
            if front.is_empty() {
                return Err("front empty for nonempty input".into());
            }
            for a in &front {
                for b in pts {
                    if qmap::nsga::dominates(b, a) {
                        return Err(format!("{b:?} dominates front member {a:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn mutation_respects_bitwidth_bounds() {
    forall(
        0x9A13,
        500,
        |r| {
            let n = r.range(1, 40);
            let seed = r.next_u64();
            (QuantConfig::uniform(n, 8), seed)
        },
        |(qc, seed)| {
            let mut g = qc.clone();
            let mut rng = Rng::new(*seed);
            for _ in 0..50 {
                qmap::nsga::mutate(&mut g, 0.5, 0.3, &mut rng);
                for &(a, w) in &g.layers {
                    if !(QMIN..=QMAX).contains(&a) || !(QMIN..=QMAX).contains(&w) {
                        return Err(format!("gene out of range: ({a},{w})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn crossover_genes_come_from_parents() {
    forall(
        0x9A14,
        300,
        |r| {
            let n = r.range(1, 40);
            let mut a = QuantConfig::uniform(n, 8);
            let mut b = QuantConfig::uniform(n, 8);
            for l in a.layers.iter_mut() {
                l.0 = QMIN + r.below(7) as u8;
                l.1 = QMIN + r.below(7) as u8;
            }
            for l in b.layers.iter_mut() {
                l.0 = QMIN + r.below(7) as u8;
                l.1 = QMIN + r.below(7) as u8;
            }
            let seed = r.next_u64();
            (a, b, seed)
        },
        |(a, b, seed)| {
            let mut rng = Rng::new(*seed);
            let child = qmap::nsga::uniform_crossover(a, b, &mut rng);
            if child.layers.len() != a.layers.len() {
                return Err("child length mismatch".into());
            }
            // the paper's genome is a linear string of *integers* (56 for
            // MobileNetV1): qa and qw cross over independently
            for (i, &(ca, cw)) in child.layers.iter().enumerate() {
                if ca != a.layers[i].0 && ca != b.layers[i].0 {
                    return Err(format!("qa gene {i} ({ca}) not from either parent"));
                }
                if cw != a.layers[i].1 && cw != b.layers[i].1 {
                    return Err(format!("qw gene {i} ({cw}) not from either parent"));
                }
            }
            Ok(())
        },
    );
}

// -------------------------------------------------------- mapper stability

#[test]
fn canonical_quant_shares_search_results() {
    // settings in the same packing-equivalence class must produce the
    // same mapper outcome (this is what makes the cache effective)
    forall(
        0xCA40,
        40,
        |r| (random_layer(r), r.next_u64()),
        |(layer, _)| {
            let arch = eyeriss(); // word 16, packing on
            let cfg = qmap::mapper::MapperConfig {
                valid_target: 50,
                max_draws: 50_000,
                seed: 11,
                shards: 1,
            };
            // 7 and 8 bits both pack 2/word -> identical canonical class
            let r7 = qmap::mapper::search(&arch, layer, &LayerQuant::uniform(7), &cfg);
            let r8 = qmap::mapper::search(&arch, layer, &LayerQuant::uniform(8), &cfg);
            if r7.best.map(|e| e.edp()) != r8.best.map(|e| e.edp()) {
                return Err("7b and 8b (same pack class) diverged".into());
            }
            Ok(())
        },
    );
}
