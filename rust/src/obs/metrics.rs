//! Aggregated hot-path counters and the Prometheus-style text
//! endpoint (`qmap worker --metrics ADDR`).
//!
//! Counters are process-global relaxed atomics, incremented *outside*
//! the RNG/evaluation path (stage counts are folded per finished
//! shard, cache probe outcomes per scheduling probe, journal timings
//! per checkpoint save) — observability never changes what the search
//! computes, only what it reports.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Every process-global counter. Names mirror the Prometheus rows in
/// [`render_prometheus`] (`qmap_<name>_total`).
#[derive(Default)]
pub struct Counters {
    // mapper cascade (folded per finished shard)
    pub shard_draws: AtomicU64,
    pub shard_spatial_rejects: AtomicU64,
    pub shard_tile_rejects: AtomicU64,
    pub shard_valid: AtomicU64,
    /// Accepted candidates whose pricing the admissible bound skipped.
    pub bound_pruned: AtomicU64,
    pub shards: AtomicU64,
    // search guidance (validity-rate folds and the reorderings they cause)
    pub guide_updates: AtomicU64,
    pub guided_reorderings: AtomicU64,
    // cache probe outcomes on the scheduling path
    pub cache_probe_hits: AtomicU64,
    pub cache_probe_negative: AtomicU64,
    pub cache_probe_misses: AtomicU64,
    // engine (per-generation deltas folded at the boundary)
    pub steals: AtomicU64,
    pub splits: AtomicU64,
    pub jobs: AtomicU64,
    // remote batch lifecycle (both driver and worker side)
    pub batches_sent: AtomicU64,
    pub batches_done: AtomicU64,
    pub batches_lost: AtomicU64,
    pub batches_served: AtomicU64,
    pub proto_errors: AtomicU64,
    pub lost_workers: AtomicU64,
    pub worker_cache_hits: AtomicU64,
    // persistent cache store (search- and worker-side)
    pub store_hits: AtomicU64,
    pub store_misses: AtomicU64,
    pub store_appends: AtomicU64,
    pub store_open_us: AtomicU64,
    // checkpoint journal
    pub ckpt_appends: AtomicU64,
    pub ckpt_append_entries: AtomicU64,
    pub ckpt_fsync_us: AtomicU64,
    pub ckpt_compactions: AtomicU64,
    // forensics
    pub dumps: AtomicU64,
}

static COUNTERS: OnceLock<Counters> = OnceLock::new();

pub fn counters() -> &'static Counters {
    COUNTERS.get_or_init(Counters::default)
}

impl Counters {
    /// Snapshot as `(name, value)` rows, fixed order.
    pub fn rows(&self) -> Vec<(&'static str, u64)> {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        vec![
            ("shard_draws", g(&self.shard_draws)),
            ("shard_spatial_rejects", g(&self.shard_spatial_rejects)),
            ("shard_tile_rejects", g(&self.shard_tile_rejects)),
            ("shard_valid", g(&self.shard_valid)),
            ("bound_pruned", g(&self.bound_pruned)),
            ("shards", g(&self.shards)),
            ("guide_updates", g(&self.guide_updates)),
            ("guided_reorderings", g(&self.guided_reorderings)),
            ("cache_probe_hits", g(&self.cache_probe_hits)),
            ("cache_probe_negative", g(&self.cache_probe_negative)),
            ("cache_probe_misses", g(&self.cache_probe_misses)),
            ("steals", g(&self.steals)),
            ("splits", g(&self.splits)),
            ("jobs", g(&self.jobs)),
            ("batches_sent", g(&self.batches_sent)),
            ("batches_done", g(&self.batches_done)),
            ("batches_lost", g(&self.batches_lost)),
            ("batches_served", g(&self.batches_served)),
            ("proto_errors", g(&self.proto_errors)),
            ("lost_workers", g(&self.lost_workers)),
            ("worker_cache_hits", g(&self.worker_cache_hits)),
            ("store_hits", g(&self.store_hits)),
            ("store_misses", g(&self.store_misses)),
            ("store_appends", g(&self.store_appends)),
            ("store_open_us", g(&self.store_open_us)),
            ("ckpt_appends", g(&self.ckpt_appends)),
            ("ckpt_append_entries", g(&self.ckpt_append_entries)),
            ("ckpt_fsync_us", g(&self.ckpt_fsync_us)),
            ("ckpt_compactions", g(&self.ckpt_compactions)),
            ("dumps", g(&self.dumps)),
        ]
    }
}

/// Render every counter in the Prometheus text exposition format.
pub fn render_prometheus() -> String {
    let mut out = String::with_capacity(2048);
    out.push_str("# qmap search-engine counters (schema ");
    out.push_str(&super::SCHEMA_VERSION.to_string());
    out.push_str(")\n");
    for (name, v) in counters().rows() {
        out.push_str("# TYPE qmap_");
        out.push_str(name);
        out.push_str("_total counter\nqmap_");
        out.push_str(name);
        out.push_str("_total ");
        out.push_str(&v.to_string());
        out.push('\n');
    }
    out
}

/// Serve [`render_prometheus`] over plain HTTP/1.0 on `addr` from a
/// background thread (the same std-only TCP machinery as the worker
/// protocol — one response per connection, then close). Returns the
/// bound local address, e.g. for `--metrics 127.0.0.1:0`.
pub fn serve(addr: &str) -> std::io::Result<String> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?.to_string();
    std::thread::Builder::new()
        .name("qmap-metrics".into())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                // drain whatever request line arrived; the response is
                // the same for every path
                let mut buf = [0u8; 1024];
                let _ = stream.read(&mut buf);
                let body = render_prometheus();
                let _ = write!(
                    stream,
                    "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                );
            }
        })?;
    Ok(local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};
    use std::net::TcpStream;
    use std::time::Duration;

    #[test]
    fn prometheus_rendering_names_every_counter() {
        counters().shard_draws.fetch_add(3, Ordering::Relaxed);
        let text = render_prometheus();
        for (name, _) in counters().rows() {
            assert!(text.contains(&format!("qmap_{name}_total ")), "missing row {name}:\n{text}");
        }
    }

    #[test]
    fn metrics_endpoint_serves_counters_over_tcp() {
        let addr = serve("127.0.0.1:0").expect("bind metrics");
        counters().batches_served.fetch_add(1, Ordering::Relaxed);
        let mut stream = TcpStream::connect(&addr).expect("connect metrics");
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(stream, "GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut lines = BufReader::new(stream).lines();
        let status = lines.next().expect("status line").expect("readable");
        assert!(status.starts_with("HTTP/1.0 200"), "{status}");
        let body: Vec<String> = lines.map_while(Result::ok).collect();
        assert!(body.iter().any(|l| l.starts_with("qmap_batches_served_total ")), "{body:?}");
        // a second scrape still answers (the listener loops)
        let mut s2 = TcpStream::connect(&addr).expect("reconnect");
        write!(s2, "GET / HTTP/1.0\r\n\r\n").unwrap();
        let mut first = String::new();
        BufReader::new(s2).read_line(&mut first).unwrap();
        assert!(first.starts_with("HTTP/1.0 200"), "{first}");
    }
}
