"""L1 Pallas kernel: fused fake-quant depthwise convolution.

MobileNet's second compute hot-spot (after the pointwise matmul) is the
3x3 depthwise conv. On TPU a depthwise conv cannot use the MXU (no
channel reduction), so the right mapping is the VPU: per-channel
shift-multiply-accumulate over the RxS window, vectorized along the
channel (lane) axis.

Kernel structure (structural TPU mapping; executed under
``interpret=True`` on CPU PJRT — see DESIGN.md §Hardware-Adaptation):

* grid over channel blocks of ``BLOCK_C`` lanes; each step holds one
  ``[B, H+R-1, W+S-1, BLOCK_C]`` padded-input tile, the ``[R, S,
  BLOCK_C]`` filter sliver and the ``[B, HO, WO, BLOCK_C]`` out tile in
  VMEM (channel-last keeps the lane axis contiguous);
* quantize(x) and quantize(w) are fused in front of the accumulation so
  the quantized operands never round-trip to HBM (the paper's
  fewer-memory-transfers insight);
* the RxS loop is unrolled at trace time (R, S static); accumulation is
  f32.

Gradients: ``custom_vjp`` with straight-through estimation, mirroring
``qmatmul``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quantize import qparams, quant_dequant

# Channel-block default: one TPU lane register row is 128 lanes wide.
BLOCK_C = 128


def _qdw_kernel(x_ref, w_ref, qp_ref, mask_ref, o_ref, *, r, s, stride, ho, wo):
    """One grid step: o[..., c-block] = fq(x) (*) fq(w) over the window.

    ``mask`` zeroes the SAME-padding ring *after* quantization: QAT
    semantics quantize the activations first and pad with true zeros, and
    fq(0) != 0 under asymmetric quantization.
    """
    qp = qp_ref[...]
    x_min, x_scale, w_min, w_scale = qp[0], qp[1], qp[2], qp[3]
    x = x_ref[...]  # [B, HP, WP, BC], already zero-padded in HBM
    w = w_ref[...]  # [R, S, BC]
    mask = mask_ref[...]  # [HP, WP] 1.0 inside, 0.0 on the pad ring
    xq = jnp.round((x - x_min) / x_scale) * x_scale + x_min
    xq = xq * mask[None, :, :, None]
    wq = jnp.round((w - w_min) / w_scale) * w_scale + w_min

    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for ri in range(r):
        for si in range(s):
            # strided window starting at (ri, si): [B, HO, WO, BC]
            win = jax.lax.slice(
                xq,
                (0, ri, si, 0),
                (xq.shape[0], ri + (ho - 1) * stride + 1, si + (wo - 1) * stride + 1, xq.shape[3]),
                (1, stride, stride, 1),
            )
            acc = acc + win * wq[ri, si, :]
    o_ref[...] = acc


def _qdwconv_impl(x, w, qa_bits, qw_bits, *, stride=1, block_c=BLOCK_C, interpret=True):
    """x: [B, H, W, C] f32; w: [R, S, C] f32; 'SAME'-style padding so that
    HO = ceil(H / stride)."""
    b, h, ww_, c = x.shape
    r, s, cw = w.shape
    assert c == cw, f"channel mismatch: {x.shape} vs {w.shape}"

    ho = -(-h // stride)
    wo = -(-ww_ // stride)
    # SAME padding totals
    pad_h = max((ho - 1) * stride + r - h, 0)
    pad_w = max((wo - 1) * stride + s - ww_, 0)
    xp = jnp.pad(
        x,
        ((0, 0), (pad_h // 2, pad_h - pad_h // 2), (pad_w // 2, pad_w - pad_w // 2), (0, 0)),
    )
    hp, wp = xp.shape[1], xp.shape[2]

    x_min, x_scale = qparams(x, qa_bits)
    w_min, w_scale = qparams(w, qw_bits)
    qp = jnp.stack([x_min, x_scale, w_min, w_scale]).astype(jnp.float32)

    bc = min(block_c, c)
    pad_c = (-c) % bc
    if pad_c:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad_c)))
    cp = c + pad_c

    # 1.0 on real pixels, 0.0 on the padding ring (see kernel docstring)
    mask = jnp.pad(
        jnp.ones((h, ww_), jnp.float32),
        ((pad_h // 2, pad_h - pad_h // 2), (pad_w // 2, pad_w - pad_w // 2)),
    )

    kernel = functools.partial(_qdw_kernel, r=r, s=s, stride=stride, ho=ho, wo=wo)
    out = pl.pallas_call(
        kernel,
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((b, hp, wp, bc), lambda i: (0, 0, 0, i)),  # input tile
            pl.BlockSpec((r, s, bc), lambda i: (0, 0, i)),  # filter sliver
            pl.BlockSpec((4,), lambda i: (0,)),  # quant scalars
            pl.BlockSpec((hp, wp), lambda i: (0, 0)),  # padding mask
        ],
        out_specs=pl.BlockSpec((b, ho, wo, bc), lambda i: (0, 0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((b, ho, wo, cp), jnp.float32),
        interpret=interpret,
    )(xp, w, qp, mask)
    return out[..., :c] if pad_c else out


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def qdwconv(x, w, qa_bits, qw_bits, stride=1):
    """Fake-quant depthwise conv, 'SAME' padding, STE gradients.

    x: [B, H, W, C]; w: [R, S, C]; qa_bits/qw_bits: traced f32 scalars.
    """
    return _qdwconv_impl(x, w, qa_bits, qw_bits, stride=stride)


def _ref_dw(x, w, stride):
    """Plain depthwise conv via conv_general_dilated (no quantization)."""
    c = x.shape[-1]
    return jax.lax.conv_general_dilated(
        x,
        w[:, :, None, :],  # [R, S, 1, C]
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def _fwd(x, w, qa_bits, qw_bits, stride):
    return _qdwconv_impl(x, w, qa_bits, qw_bits, stride=stride), (x, w, qa_bits, qw_bits)


def _bwd(stride, res, g):
    x, w, qa_bits, qw_bits = res
    xq = quant_dequant(x, qa_bits)
    wq = quant_dequant(w, qw_bits)
    # STE: differentiate the dequantized conv wrt its operands
    _, vjp = jax.vjp(lambda xx, ww: _ref_dw(xx, ww, stride), xq, wq)
    gx, gw = vjp(g)
    return gx, gw, jnp.zeros_like(qa_bits), jnp.zeros_like(qw_bits)


qdwconv.defvjp(_fwd, _bwd)
