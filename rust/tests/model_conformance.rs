//! Model-conformance suite: drives the explicit FSM models in
//! `qmap::model` and the *real* engine components from the same event
//! stream, checking the Projection-style retraction invariant
//!
//! ```text
//! map_state(apply(x, e)) == step(map_state(x), e)
//! ```
//!
//! at every edge of a bounded **exhaustive** BFS over event
//! interleavings (`qmap::model::conform`). Where the randomized suites
//! (`tests/distributed_stateful.rs`) *sample* interleavings, these
//! tests *cover* them for small scopes — every delivery order, every
//! loss point, every crash/tear/resume position up to the documented
//! depth — and on divergence emit a minimized, replayable script
//! (`model_cex_<name>.script`) plus an `obs` flight-recorder dump.
//!
//! Replay a committed or CI-uploaded counterexample with
//! `QMAP_MODEL_REPLAY=<script> cargo test --test model_conformance`.
//!
//! Three projections bind model to SUT:
//! * `batch` model  ↔ one real [`BatchLedger`] fed real
//!   [`ShardOutcome`]s, with `finalize` pinned bit-identical to the
//!   serial `mapper::search` reference in every interleaving.
//! * `window` model ↔ [`PipelineWindow`] + one ledger per job — the
//!   adaptive-depth timing stamps are projected from the real
//!   `sent_at`/`first_out` bookkeeping, so a drain leak on loss is a
//!   retraction mismatch, not a sampled flake.
//! * `journal` model ↔ a real [`Checkpointer`] + [`MapperCache`] on a
//!   real temp file, including compaction, torn-tail crashes
//!   (truncating the file mid-mark exactly like the crash would), and
//!   resume.

use qmap::arch::presets::toy;
use qmap::arch::Arch;
use qmap::engine::checkpoint::SearchIdent;
use qmap::engine::remote::{BatchLedger, PipelineWindow};
use qmap::engine::Checkpointer;
use qmap::mapper::cache::{MapperCache, WorkloadKey};
use qmap::mapper::{self, MapperConfig, MapperResult, ShardOutcome, ShardSpec};
use qmap::mapping::mapspace::MapSpace;
use qmap::mapping::LayerContext;
use qmap::model::batch::{BatchEvent, BatchModel, BatchState};
use qmap::model::journal::{JournalEvent, JournalModel, JournalState, INIT_GEN};
use qmap::model::window::{JobView, WindowEvent, WindowModel, WindowState};
use qmap::model::{
    conform, explore, parse_script, replay_conformance, Budget, Fsm, Product, Projection,
};
use qmap::nsga::{Individual, NsgaConfig, SearchState};
use qmap::objective::{ObjectiveSpec, ObjectiveVec};
use qmap::quant::{LayerQuant, QuantConfig};
use qmap::util::json::{parse, Json};
use qmap::util::rng::Rng;
use qmap::workload::ConvLayer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

// ------------------------------------------------- shared shard pool

fn shard_workload(shards: usize) -> (Arch, ConvLayer, LayerQuant, MapperConfig) {
    let arch = toy();
    let layer = ConvLayer::conv("c1", 3, 8, 3, 16, 1);
    let q = LayerQuant::uniform(4).canonical(arch.word_bits, arch.bit_packing);
    let cfg = MapperConfig {
        valid_target: 30,
        max_draws: 30_000,
        seed: 11,
        shards,
    };
    (arch, layer, q, cfg)
}

/// Precomputed real shard work: `run_shard` is pure, so every
/// conformance edge can deliver the same outcomes a live worker would
/// stream, without re-searching per edge.
struct ShardPool {
    specs: Vec<ShardSpec>,
    outcomes: Vec<ShardOutcome>,
    /// The serial `mapper::search` result every merge must hit, bit
    /// for bit, in every interleaving.
    reference: MapperResult,
}

impl ShardPool {
    fn new(shards: usize) -> ShardPool {
        let (arch, layer, q, cfg) = shard_workload(shards);
        let space = MapSpace::of(&arch);
        let lctx = LayerContext::new(&arch, &layer, &q);
        let specs = mapper::shard_plan(&cfg, cfg.seed ^ mapper::workload_hash(&layer, &q));
        let outcomes = specs
            .iter()
            .map(|s| mapper::run_shard(&space, &lctx, s))
            .collect();
        let reference = mapper::search(&arch, &layer, &q, &cfg);
        ShardPool {
            specs,
            outcomes,
            reference,
        }
    }
}

fn same_result(got: &MapperResult, want: &MapperResult) -> Result<(), String> {
    let gb = got.best.as_ref().map(|e| e.edp().to_bits());
    let wb = want.best.as_ref().map(|e| e.edp().to_bits());
    if got.valid != want.valid
        || got.draws != want.draws
        || gb != wb
        || got.best_mapping != want.best_mapping
    {
        return Err(format!(
            "merged result diverged from the serial reference: \
             valid {}/{}, draws {}/{}, edp bits {gb:?}/{wb:?}",
            got.valid, want.valid, got.draws, want.draws
        ));
    }
    Ok(())
}

// ------------------------------------------- batch ledger projection

struct LedgerProjection {
    model: BatchModel,
    pool: Arc<ShardPool>,
}

#[derive(Clone)]
struct LedgerSut {
    ledger: BatchLedger,
    done: bool,
    lost: bool,
    finalized: bool,
}

impl LedgerSut {
    fn live(&self) -> bool {
        !self.done && !self.lost && !self.finalized
    }
}

impl Projection for LedgerProjection {
    type Model = BatchModel;
    type Sut = LedgerSut;

    fn model(&self) -> &BatchModel {
        &self.model
    }

    fn init_sut(&self) -> LedgerSut {
        LedgerSut {
            ledger: BatchLedger::new(self.pool.specs.clone()),
            done: false,
            lost: false,
            finalized: false,
        }
    }

    fn apply(&self, sut: &mut LedgerSut, e: &BatchEvent) -> Result<(), String> {
        match e {
            BatchEvent::Deliver(i) => {
                if sut.live() && *i < self.pool.specs.len() {
                    let fresh = sut.ledger.missing().contains(i);
                    match sut.ledger.deliver(*i, self.pool.outcomes[*i].clone()) {
                        Ok(filled) if filled == fresh => {}
                        Ok(filled) => {
                            return Err(format!(
                                "deliver({i}) returned Ok({filled}) but the slot was {}",
                                if fresh { "empty" } else { "filled" }
                            ))
                        }
                        Err(err) => return Err(format!("deliver({i}) refused: {err}")),
                    }
                }
            }
            BatchEvent::DeliverBogus => {
                if sut.live() {
                    let bogus = self.pool.specs.len();
                    if sut
                        .ledger
                        .deliver(bogus, self.pool.outcomes[0].clone())
                        .is_ok()
                    {
                        return Err(format!("out-of-range shard {bogus} was accepted"));
                    }
                    sut.lost = true;
                }
            }
            BatchEvent::Done => {
                if sut.live() {
                    sut.done = true;
                }
            }
            BatchEvent::Lose => {
                if sut.live() {
                    sut.lost = true;
                }
            }
            BatchEvent::Finalize => {
                if (sut.done || sut.lost) && !sut.finalized {
                    sut.finalized = true;
                    let merged = sut
                        .ledger
                        .clone()
                        .finalize(|i, _| self.pool.outcomes[i].clone());
                    same_result(&merged, &self.pool.reference)?;
                }
            }
        }
        Ok(())
    }

    fn map_state(&self, sut: &LedgerSut) -> BatchState {
        let missing = sut.ledger.missing();
        BatchState {
            delivered: (0..self.pool.specs.len())
                .map(|i| !missing.contains(&i))
                .collect(),
            done: sut.done,
            lost: sut.lost,
            finalized: sut.finalized,
        }
    }
}

/// Every interleaving of shard deliveries, duplicates, bogus indices,
/// early `done`, loss, and the refill sweep — exhaustively, each
/// `Finalize` pinned bit-identical to the serial reference.
#[test]
fn batch_ledger_conforms_exhaustively() {
    let pool = Arc::new(ShardPool::new(3));
    let p = LedgerProjection {
        model: BatchModel {
            shards: pool.specs.len(),
        },
        pool,
    };
    match conform(&p, &Budget::new(12, 100_000)) {
        Ok(cov) => {
            assert!(cov.complete, "batch scope must be exhausted: {cov:?}");
            assert!(cov.deepest >= 5, "got depth {}", cov.deepest);
        }
        Err(v) => v.fail_with_script(p.model()),
    }
}

// ---------------------------------------- pipeline window projection

struct WindowProjection {
    model: WindowModel,
    pool: Arc<ShardPool>,
}

#[derive(Clone)]
struct WindowSut {
    win: PipelineWindow,
    ledgers: Vec<BatchLedger>,
    /// Driver-side batch id per claimed job (`Some(0)` = the pseudo id
    /// of a failed send).
    ids: Vec<Option<u64>>,
    completed: Vec<bool>,
    next_id: u64,
    lost: bool,
    swept: bool,
}

impl WindowSut {
    fn live(&self) -> bool {
        !self.lost && !self.swept
    }
}

impl Projection for WindowProjection {
    type Model = WindowModel;
    type Sut = WindowSut;

    fn model(&self) -> &WindowModel {
        &self.model
    }

    fn init_sut(&self) -> WindowSut {
        WindowSut {
            win: PipelineWindow::new(self.model.depth),
            ledgers: (0..self.model.jobs)
                .map(|_| BatchLedger::new(self.pool.specs.clone()))
                .collect(),
            ids: vec![None; self.model.jobs],
            completed: vec![false; self.model.jobs],
            next_id: 0,
            lost: false,
            swept: false,
        }
    }

    fn apply(&self, sut: &mut WindowSut, e: &WindowEvent) -> Result<(), String> {
        match e {
            WindowEvent::Send => {
                if sut.live() && sut.win.len() < self.model.depth {
                    if let Some(j) = sut.ids.iter().position(|id| id.is_none()) {
                        sut.next_id += 1;
                        let id = sut.next_id;
                        sut.win.on_sent(id, j);
                        sut.ids[j] = Some(id);
                    }
                }
            }
            WindowEvent::SendFail => {
                if sut.live() && sut.win.len() < self.model.depth {
                    if let Some(j) = sut.ids.iter().position(|id| id.is_none()) {
                        // the pump's send-failure path: the claim
                        // stands under pseudo id 0, the connection is
                        // condemned and the window drained
                        sut.win.on_send_failed(j);
                        sut.ids[j] = Some(0);
                        sut.lost = true;
                        let drained = sut.win.on_loss();
                        if !drained.contains(&(0, j)) {
                            return Err(format!(
                                "failed send for job {j} not owed on loss: {drained:?}"
                            ));
                        }
                    }
                }
            }
            WindowEvent::Outcome { job, shard } => {
                if sut.live() && *job < sut.ids.len() && *shard < self.model.shards {
                    if let Some(id) = sut.ids[*job] {
                        if let Some(wi) = sut.win.on_outcome(id) {
                            if wi != *job {
                                return Err(format!(
                                    "outcome for batch {id} routed to job {wi}, not {job}"
                                ));
                            }
                            let fresh = sut.ledgers[*job].missing().contains(shard);
                            match sut.ledgers[*job]
                                .deliver(*shard, self.pool.outcomes[*shard].clone())
                            {
                                Ok(filled) if filled == fresh => {}
                                Ok(filled) => {
                                    return Err(format!(
                                        "job {job} deliver({shard}) returned Ok({filled}) \
                                         for a {} slot",
                                        if fresh { "empty" } else { "filled" }
                                    ))
                                }
                                Err(err) => {
                                    return Err(format!("job {job} deliver refused: {err}"))
                                }
                            }
                        }
                    }
                }
            }
            WindowEvent::StaleOutcome { job } => {
                if sut.live() && *job < sut.ids.len() && sut.completed[*job] {
                    if let Some(id) = sut.ids[*job] {
                        if sut.win.on_outcome(id).is_some() {
                            return Err(format!(
                                "stale outcome for completed job {job} treated as live"
                            ));
                        }
                    }
                }
            }
            WindowEvent::Done { job } => {
                if sut.live() && *job < sut.ids.len() {
                    if let Some(id) = sut.ids[*job] {
                        if let Some((wi, _rtt, _serve)) = sut.win.on_done(id) {
                            if wi != *job {
                                return Err(format!(
                                    "done for batch {id} routed to job {wi}, not {job}"
                                ));
                            }
                            sut.completed[*job] = true;
                        }
                    }
                }
            }
            WindowEvent::StaleDone { job } => {
                if sut.live() && *job < sut.ids.len() && sut.completed[*job] {
                    if let Some(id) = sut.ids[*job] {
                        if sut.win.on_done(id).is_some() {
                            return Err(format!(
                                "stale done for completed job {job} treated as live"
                            ));
                        }
                    }
                }
            }
            WindowEvent::Lose => {
                if sut.live() {
                    sut.lost = true;
                    sut.win.on_loss();
                }
            }
            WindowEvent::Sweep => {
                if !sut.swept && (sut.lost || sut.win.is_empty()) {
                    sut.swept = true;
                    // the driver's sweep: every claimed job refills its
                    // missing shards and merges bit-identically
                    for j in 0..sut.ledgers.len() {
                        if sut.ids[j].is_some() {
                            let merged = sut.ledgers[j]
                                .clone()
                                .finalize(|i, _| self.pool.outcomes[i].clone());
                            same_result(&merged, &self.pool.reference)
                                .map_err(|e| format!("job {j}: {e}"))?;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn map_state(&self, sut: &WindowSut) -> WindowState {
        let firsts = sut.win.tracked_first_outcomes();
        WindowState {
            inflight: sut
                .win
                .inflight_entries()
                .iter()
                .map(|&(id, work)| (work, firsts.contains(&id)))
                .collect(),
            jobs: (0..self.model.jobs)
                .map(|j| {
                    let missing = sut.ledgers[j].missing();
                    JobView {
                        claimed: sut.ids[j].is_some(),
                        delivered: (0..self.model.shards)
                            .map(|s| !missing.contains(&s))
                            .collect(),
                        completed: sut.completed[j],
                    }
                })
                .collect(),
            lost: sut.lost,
            swept: sut.swept,
            timings: sut.win.tracked_sends().len() + firsts.len(),
        }
    }
}

fn window_projection() -> WindowProjection {
    let pool = Arc::new(ShardPool::new(2));
    WindowProjection {
        model: WindowModel {
            jobs: 3,
            shards: pool.specs.len(),
            depth: 2,
        },
        pool,
    }
}

/// The acceptance scope: worker loss × pipelining at depth ≤ 2,
/// exhaustively — every send/outcome/done/stale/loss interleaving of 3
/// jobs through a depth-2 window, with the real adaptive-depth timing
/// bookkeeping projected back onto the model at every edge. A stamp
/// leaked past a loss (the old EWMA bookkeeping bug) is a retraction
/// mismatch here, at the exact first edge that leaks it.
#[test]
fn pipeline_window_conforms_exhaustively() {
    let p = window_projection();
    match conform(&p, &Budget::new(14, 400_000)) {
        Ok(cov) => {
            assert!(cov.complete, "window scope must be exhausted: {cov:?}");
            // a fault-free full run is 13 events: 3 sends, 6 outcomes,
            // 3 dones, the sweep
            assert!(cov.deepest >= 13, "got depth {}", cov.deepest);
        }
        Err(v) => v.fail_with_script(p.model()),
    }
}

// ----------------------------------------- checkpoint journal SUT

/// Shared immutable half of the journal SUT: the search identity, the
/// churn + fresh workloads with their precomputed results, and each
/// key's exact journal frame line (`{"insert":{...}}` is byte-stable
/// for a given key+result, which is what lets `map_state` read the
/// file back into model terms).
struct JournalPool {
    arch: Arch,
    cfg: MapperConfig,
    ident: SearchIdent,
    /// The churn key: re-inserted repeatedly, one cache entry.
    dup: (ConvLayer, LayerQuant, MapperResult, String),
    /// Single-use fresh keys.
    fresh: Vec<(ConvLayer, LayerQuant, MapperResult, String)>,
    slack: u8,
    max_gen: u8,
    counter: AtomicUsize,
}

fn sig_line(
    arch: &Arch,
    layer: &ConvLayer,
    q: &LayerQuant,
    cfg: &MapperConfig,
    r: &MapperResult,
) -> String {
    let c = MapperCache::new();
    c.insert_search(arch, layer, q, cfg, r);
    let mut es = c.entries_json();
    assert_eq!(es.len(), 1, "one key, one entry");
    Json::obj(vec![("insert", es.remove(0))]).to_string()
}

impl JournalPool {
    fn new(fresh_keys: usize, slack: u8, max_gen: u8) -> JournalPool {
        let arch = toy();
        let cfg = MapperConfig {
            valid_target: 20,
            max_draws: 20_000,
            seed: 5,
            shards: 1,
        };
        let q = LayerQuant::uniform(8);
        let mk = |out: u64| {
            let l = ConvLayer::fc("fc", 16, out);
            let r = mapper::search(&arch, &l, &q, &cfg);
            let sig = sig_line(&arch, &l, &q, &cfg, &r);
            (l, q, r, sig)
        };
        JournalPool {
            ident: SearchIdent::new(
                &arch,
                4,
                &ObjectiveSpec::default(),
                &MapperConfig::default(),
                &NsgaConfig::default(),
            ),
            dup: mk(10),
            fresh: (0..fresh_keys as u64).map(|i| mk(12 + 2 * i)).collect(),
            arch,
            cfg,
            slack,
            max_gen,
            counter: AtomicUsize::new(0),
        }
    }
}

fn search_state(generation: usize) -> SearchState {
    SearchState {
        generation,
        pop: vec![Individual {
            genome: QuantConfig::uniform(4, 4),
            objectives: ObjectiveVec::raw(vec![1.0, 2.0]),
        }],
        rng: Rng::new(0xFEED_F00D),
    }
}

/// Read the journal file back into model terms: complete mark
/// generations, complete insert-frame lines, and whether the tail is
/// torn — mirroring exactly what `Checkpointer::load` would accept.
fn parse_journal(path: &str) -> (Vec<u8>, Vec<String>, bool) {
    let text = std::fs::read_to_string(path).expect("journal file exists");
    let mut torn = !text.ends_with('\n');
    let lines: Vec<&str> = text.lines().collect();
    let mut marks = Vec::new();
    let mut inserts = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        match parse(line) {
            Ok(f) => {
                if let Some(g) = f.get("mark").get("generation").as_f64() {
                    marks.push(g as u8);
                } else if !matches!(f.get("insert"), Json::Null) {
                    inserts.push((*line).to_string());
                }
            }
            Err(_) if i + 1 == lines.len() => torn = true,
            Err(e) => panic!("corrupt middle frame in model journal: {e}: {line}"),
        }
    }
    (marks, inserts, torn)
}

/// The live half: a real `Checkpointer` + `MapperCache` on a private
/// temp file. `Clone` (required by the BFS, which forks one SUT per
/// explored edge) rebuilds the state by replaying the event history
/// through the real API on a fresh file — there is no snapshot
/// shortcut that wouldn't bypass the very code under test.
struct JournalSut {
    pool: Arc<JournalPool>,
    path: String,
    ckpt: Checkpointer,
    cache: MapperCache,
    down: bool,
    // driver-side mirrors for the model fields with no filesystem
    // observable; everything they feed (frame counts, entries, marks)
    // is cross-checked against the real file at every Save/Resume
    pending_dup: u8,
    pending_fresh: u8,
    used_fresh: u8,
    next_gen: u8,
    history: Vec<JournalEvent>,
}

fn fresh_journal_sut(pool: &Arc<JournalPool>) -> JournalSut {
    let n = pool.counter.fetch_add(1, Ordering::Relaxed);
    let mut p = std::env::temp_dir();
    p.push(format!(
        "qmap_model_journal_{}_{n}.json",
        std::process::id()
    ));
    let path = p.to_string_lossy().into_owned();
    let ckpt = Checkpointer::new(path.as_str()).with_compact_slack(pool.slack as usize);
    let cache = MapperCache::new();
    ckpt.save(&search_state(INIT_GEN as usize), &cache, &pool.ident)
        .expect("initial save");
    JournalSut {
        pool: pool.clone(),
        path,
        ckpt,
        cache,
        down: false,
        pending_dup: 0,
        pending_fresh: 0,
        used_fresh: 0,
        next_gen: INIT_GEN + 1,
        history: Vec::new(),
    }
}

impl JournalSut {
    /// The process dies: appender and cache are gone, the file stays.
    fn kill(&mut self) {
        self.ckpt =
            Checkpointer::new(self.path.as_str()).with_compact_slack(self.pool.slack as usize);
        self.cache = MapperCache::new();
        self.down = true;
        self.pending_dup = 0;
        self.pending_fresh = 0;
    }

    fn raw_apply(&mut self, e: &JournalEvent) -> Result<(), String> {
        let pool = self.pool.clone();
        match e {
            JournalEvent::InsertDup => {
                if !self.down {
                    let (l, q, r, _) = &pool.dup;
                    self.cache.insert_search(&pool.arch, l, q, &pool.cfg, r);
                    self.pending_dup += 1;
                }
            }
            JournalEvent::InsertFresh => {
                if !self.down && (self.used_fresh as usize) < pool.fresh.len() {
                    let (l, q, r, _) = &pool.fresh[self.used_fresh as usize];
                    self.cache.insert_search(&pool.arch, l, q, &pool.cfg, r);
                    self.pending_fresh += 1;
                    self.used_fresh += 1;
                }
            }
            JournalEvent::Save => {
                if !self.down && self.next_gen <= pool.max_gen {
                    let st = search_state(self.next_gen as usize);
                    self.ckpt
                        .save(&st, &self.cache, &pool.ident)
                        .map_err(|err| format!("save: {err}"))?;
                    if !self.ckpt.journal_armed() {
                        return Err("save left the appender unarmed".to_string());
                    }
                    self.pending_dup = 0;
                    self.pending_fresh = 0;
                    self.next_gen += 1;
                }
            }
            JournalEvent::Crash => {
                if !self.down {
                    self.kill();
                }
            }
            JournalEvent::Tear => {
                if !self.down {
                    let (marks, _, torn) = parse_journal(&self.path);
                    if !torn && marks.len() >= 2 {
                        // cut the file inside the final mark line —
                        // the crash-mid-append the loader must survive
                        let text = std::fs::read_to_string(&self.path)
                            .map_err(|err| err.to_string())?;
                        let cut = text.rfind("{\"mark\":").ok_or("no mark line to tear")?;
                        std::fs::write(&self.path, &text[..cut + 9])
                            .map_err(|err| err.to_string())?;
                        self.kill();
                    }
                }
            }
            JournalEvent::Resume => {
                if self.down {
                    let (marks, _, torn) = parse_journal(&self.path);
                    if !marks.is_empty() {
                        let ckpt = Checkpointer::new(self.path.as_str())
                            .with_compact_slack(pool.slack as usize);
                        let cache = MapperCache::new();
                        let st = ckpt
                            .load(&pool.ident, &cache)
                            .map_err(|err| format!("resume: {err}"))?;
                        if st.generation as u8 != *marks.last().expect("non-empty") {
                            return Err(format!(
                                "resumed at generation {} but the last complete mark is {}",
                                st.generation,
                                marks.last().expect("non-empty")
                            ));
                        }
                        if ckpt.journal_armed() == torn {
                            return Err(format!(
                                "armed={} after a resume with torn={torn}",
                                ckpt.journal_armed()
                            ));
                        }
                        self.ckpt = ckpt;
                        self.cache = cache;
                        self.down = false;
                        self.pending_dup = 0;
                        self.pending_fresh = 0;
                        self.next_gen = st.generation as u8 + 1;
                    }
                }
            }
        }
        Ok(())
    }
}

impl Clone for JournalSut {
    fn clone(&self) -> JournalSut {
        let mut s = fresh_journal_sut(&self.pool);
        for e in &self.history {
            s.raw_apply(e)
                .expect("replaying a previously-accepted event history");
        }
        s.history = self.history.clone();
        s
    }
}

impl Drop for JournalSut {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

struct JournalProjection {
    model: JournalModel,
    pool: Arc<JournalPool>,
}

impl Projection for JournalProjection {
    type Model = JournalModel;
    type Sut = JournalSut;

    fn model(&self) -> &JournalModel {
        &self.model
    }

    fn init_sut(&self) -> JournalSut {
        fresh_journal_sut(&self.pool)
    }

    fn apply(&self, sut: &mut JournalSut, e: &JournalEvent) -> Result<(), String> {
        sut.history.push(e.clone());
        sut.raw_apply(e)
    }

    fn map_state(&self, sut: &JournalSut) -> JournalState {
        let (marks, insert_lines, torn) = parse_journal(&sut.path);
        let pool = &sut.pool;
        let probe = |l: &ConvLayer, q: &LayerQuant| {
            sut.cache
                .probe_key(WorkloadKey::of(&pool.arch, l, q), &pool.cfg)
                .is_some()
        };
        JournalState {
            file_inserts: insert_lines.len() as u8,
            file_fresh: pool
                .fresh
                .iter()
                .filter(|f| insert_lines.iter().any(|l| l == &f.3))
                .count() as u8,
            file_has_dup: insert_lines.iter().any(|l| l == &pool.dup.3),
            marks,
            torn,
            down: sut.down,
            armed: sut.ckpt.journal_armed(),
            appended: sut.ckpt.journal_appended().unwrap_or(0) as u8,
            live_fresh: pool.fresh.iter().filter(|f| probe(&f.0, &f.1)).count() as u8,
            live_has_dup: probe(&pool.dup.0, &pool.dup.1),
            pending_dup: sut.pending_dup,
            pending_fresh: sut.pending_fresh,
            used_fresh: sut.used_fresh,
            next_gen: sut.next_gen,
        }
    }
}

fn journal_projection() -> JournalProjection {
    // the scope is deliberately small and NOT env-scalable: every
    // explored edge forks the SUT by replaying its history through
    // real fsync'd saves, so cost grows with states × depth. Slack 0
    // forces compaction inside the scope; one fresh key separates
    // frames from entries; max_gen 6 bounds save chains.
    let pool = Arc::new(JournalPool::new(1, 0, 6));
    JournalProjection {
        model: JournalModel {
            slack: pool.slack,
            fresh_pool: pool.fresh.len() as u8,
            max_gen: pool.max_gen,
        },
        pool,
    }
}

/// Every interleaving of insert/save/compaction/crash/tear/resume to
/// depth 6 against a **real** checkpoint journal on disk: the file is
/// parsed back into model terms at every edge, so a dropped mark, a
/// miscounted frame, an appender left armed over a torn tail, or a
/// resume landing on the wrong generation is a retraction mismatch at
/// the first edge that causes it — this is the scope that contains
/// torn-tail-immediately-after-compaction.
#[test]
fn checkpoint_journal_conforms_exhaustively() {
    let p = journal_projection();
    match conform(&p, &Budget::new(6, 20_000)) {
        Ok(cov) => {
            assert!(cov.complete, "journal scope must be exhausted: {cov:?}");
            assert!(cov.deepest >= 6, "got depth {}", cov.deepest);
            // the scope must actually contain a compaction and a tear:
            // churn 3 saves deep compacts (3 frames > 0 + 2·1 entries)
            assert!(cov.states > 100, "suspiciously small: {cov:?}");
        }
        Err(v) => v.fail_with_script(p.model()),
    }
}

// ------------------------------------------------ composed coverage

/// Cross-product coverage: the pipelined window interleaved with the
/// checkpoint journal (pure models — the conformance of each side is
/// pinned by the tests above). Depth 8 here means *every* schedule of
/// 8 combined events — worker loss between any two journal saves, a
/// crash mid-window, a resume while a batch streams — which is the
/// composed scope the acceptance floor (depth ≥ 6) asks for.
/// `QMAP_MODEL_DEPTH`/`QMAP_MODEL_STATES` raise it in CI.
#[test]
fn window_x_journal_composed_coverage() {
    let wm = WindowModel {
        jobs: 2,
        shards: 2,
        depth: 2,
    };
    let jm = JournalModel {
        slack: 0,
        fresh_pool: 1,
        max_gen: 6,
    };
    let p = Product { a: &wm, b: &jm };
    let cov = match explore(&p, &Budget::from_env(8, 400_000)) {
        Ok(cov) => cov,
        Err(v) => v.fail_with_script(&p),
    };
    assert!(cov.complete, "composed scope must be exhausted: {cov:?}");
    assert!(cov.deepest >= 6, "acceptance floor: got depth {}", cov.deepest);
}

// --------------------------------------------------------- replay

/// Replays a counterexample script (from a CI artifact or a committed
/// regression) through the same projections the exhaustive runs use:
/// `QMAP_MODEL_REPLAY=model_cex_window.script cargo test --test
/// model_conformance`. Without the env var this test is a no-op, so
/// the suite stays deterministic in CI.
#[test]
fn replay_counterexample_script_from_env() {
    let Ok(path) = std::env::var("QMAP_MODEL_REPLAY") else {
        return;
    };
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("QMAP_MODEL_REPLAY={path}: {e}"));
    let head = text.lines().next().unwrap_or("");
    let fail = |i: usize, msg: String| {
        panic!("replay of {path} diverged after event {i}: {msg}")
    };
    match head {
        "model:batch" => {
            let pool = Arc::new(ShardPool::new(3));
            let p = LedgerProjection {
                model: BatchModel {
                    shards: pool.specs.len(),
                },
                pool,
            };
            let trace = parse_script(p.model(), &text).expect("parse script");
            if let Err((i, msg)) = replay_conformance(&p, &trace) {
                fail(i, msg);
            }
        }
        "model:window" => {
            let p = window_projection();
            let trace = parse_script(p.model(), &text).expect("parse script");
            if let Err((i, msg)) = replay_conformance(&p, &trace) {
                fail(i, msg);
            }
        }
        "model:journal" => {
            let p = journal_projection();
            let trace = parse_script(p.model(), &text).expect("parse script");
            if let Err((i, msg)) = replay_conformance(&p, &trace) {
                fail(i, msg);
            }
        }
        "model:window_x_journal" => {
            let wm = WindowModel {
                jobs: 2,
                shards: 2,
                depth: 2,
            };
            let jm = JournalModel {
                slack: 0,
                fresh_pool: 1,
                max_gen: 6,
            };
            let p = Product { a: &wm, b: &jm };
            let trace = parse_script(&p, &text).expect("parse script");
            if let Err((i, msg)) = qmap::model::replay(&p, &trace) {
                fail(i, msg);
            }
        }
        other => panic!("{path}: unknown script header '{other}'"),
    }
    println!("replayed {path} cleanly — the divergence is fixed");
}
