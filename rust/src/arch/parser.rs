//! Parser for accelerator text specifications (TOML subset).
//!
//! The paper feeds accelerators to the framework "in form of a text
//! specification"; ours look like:
//!
//! ```toml
//! name = "eyeriss"
//! word_bits = 16
//! mac_energy_pj = 2.2
//! bit_packing = true
//!
//! [[level]]
//! name = "pe_spad"
//! capacity = { weights = 224, inputs = 12, outputs = 24 }
//! access_energy_pj = [0.96, 0.48, 0.72]
//! bandwidth_words = 2.0
//! fanout = 1
//! keeps = ["weights", "inputs", "outputs"]
//! ```
//!
//! Supported TOML subset: top-level `key = value`, `[[level]]` array of
//! tables, values = string / number / bool / array / inline table /
//! `"unbounded"`. Comments with `#`.

use super::{Arch, Capacity, Level};
use crate::workload::Dim;
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
enum Val {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Val>),
    Table(BTreeMap<String, Val>),
}

impl Val {
    fn num(&self) -> Result<f64, String> {
        match self {
            Val::Num(x) => Ok(*x),
            _ => Err(format!("expected number, got {self:?}")),
        }
    }
    fn str_(&self) -> Result<&str, String> {
        match self {
            Val::Str(s) => Ok(s),
            _ => Err(format!("expected string, got {self:?}")),
        }
    }
    fn boolean(&self) -> Result<bool, String> {
        match self {
            Val::Bool(b) => Ok(*b),
            _ => Err(format!("expected bool, got {self:?}")),
        }
    }
    fn arr(&self) -> Result<&[Val], String> {
        match self {
            Val::Arr(v) => Ok(v),
            _ => Err(format!("expected array, got {self:?}")),
        }
    }
}

fn parse_value(s: &str) -> Result<Val, String> {
    let s = s.trim();
    if s == "true" {
        return Ok(Val::Bool(true));
    }
    if s == "false" {
        return Ok(Val::Bool(false));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Val::Str(body.to_string()));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let items = split_top_level(body)?;
        return Ok(Val::Arr(
            items
                .into_iter()
                .filter(|i| !i.trim().is_empty())
                .map(|i| parse_value(&i))
                .collect::<Result<_, _>>()?,
        ));
    }
    if let Some(body) = s.strip_prefix('{') {
        let body = body.strip_suffix('}').ok_or("unterminated inline table")?;
        let mut m = BTreeMap::new();
        for item in split_top_level(body)? {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (k, v) = item
                .split_once('=')
                .ok_or_else(|| format!("bad inline-table entry '{item}'"))?;
            m.insert(k.trim().to_string(), parse_value(v)?);
        }
        return Ok(Val::Table(m));
    }
    // bare number (allow underscores as digit separators, like TOML)
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Val::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

/// Split on commas not nested inside brackets/braces/strings.
fn split_top_level(s: &str) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' | '{' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' | '}' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_str => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if depth != 0 || in_str {
        return Err("unbalanced brackets in value".into());
    }
    out.push(cur);
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn dim_from_str(s: &str) -> Result<Dim, String> {
    match s {
        "N" => Ok(Dim::N),
        "K" | "M" => Ok(Dim::K), // accept Timeloop's M alias
        "C" => Ok(Dim::C),
        "R" => Ok(Dim::R),
        "S" => Ok(Dim::S),
        "P" => Ok(Dim::P),
        "Q" => Ok(Dim::Q),
        _ => Err(format!("unknown dim '{s}'")),
    }
}

fn tensor_index(s: &str) -> Result<usize, String> {
    match s {
        "weights" => Ok(0),
        "inputs" => Ok(1),
        "outputs" => Ok(2),
        _ => Err(format!("unknown tensor '{s}'")),
    }
}

fn build_level(tbl: &BTreeMap<String, Val>) -> Result<Level, String> {
    let get = |k: &str| tbl.get(k).ok_or_else(|| format!("level missing '{k}'"));

    let capacity = match get("capacity")? {
        Val::Str(s) if s == "unbounded" => Capacity::Unbounded,
        Val::Num(x) => Capacity::Shared(*x as u64),
        Val::Table(m) => {
            let mut ws = [0u64; 3];
            for (k, v) in m {
                ws[tensor_index(k)?] = v.num()? as u64;
            }
            Capacity::PerTensor(ws)
        }
        other => return Err(format!("bad capacity {other:?}")),
    };

    let energies = get("access_energy_pj")?;
    let access_energy_pj = match energies {
        Val::Num(x) => [*x; 3],
        Val::Arr(v) if v.len() == 3 => [v[0].num()?, v[1].num()?, v[2].num()?],
        other => return Err(format!("bad access_energy_pj {other:?}")),
    };

    let fanout = tbl.get("fanout").map(|v| v.num()).transpose()?.unwrap_or(1.0) as u64;
    let spatial_dims = match tbl.get("spatial_dims") {
        None => vec![],
        Some(v) => v
            .arr()?
            .iter()
            .map(|d| dim_from_str(d.str_()?))
            .collect::<Result<_, _>>()?,
    };
    let mut keeps = [false; 3];
    for k in get("keeps")?.arr()? {
        keeps[tensor_index(k.str_()?)?] = true;
    }

    Ok(Level {
        name: get("name")?.str_()?.to_string(),
        capacity,
        access_energy_pj,
        bandwidth_words: tbl
            .get("bandwidth_words")
            .map(|v| v.num())
            .transpose()?
            .unwrap_or(1.0),
        fanout,
        spatial_dims,
        multicast: tbl
            .get("multicast")
            .map(|v| v.boolean())
            .transpose()?
            .unwrap_or(false),
        keeps,
    })
}

/// Parse an architecture from its text specification.
pub fn parse_arch(src: &str) -> Result<Arch, String> {
    let mut top: BTreeMap<String, Val> = BTreeMap::new();
    let mut levels: Vec<BTreeMap<String, Val>> = Vec::new();
    let mut cur: Option<&mut BTreeMap<String, Val>> = None;

    // Pass 1: gather multi-line logical lines (arrays/tables may span
    // physical lines only if re-joined; we require single-line values but
    // tolerate trailing commas).
    for (ln, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line == "[[level]]" {
            levels.push(BTreeMap::new());
            cur = None; // re-borrow below
            continue;
        }
        if line.starts_with('[') {
            return Err(format!("line {}: unsupported table '{line}'", ln + 1));
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
        let val = parse_value(v).map_err(|e| format!("line {}: {e}", ln + 1))?;
        let target = if levels.is_empty() {
            &mut top
        } else {
            let _ = &mut cur;
            levels.last_mut().unwrap()
        };
        target.insert(k.trim().to_string(), val);
    }

    let name = top
        .get("name")
        .ok_or("missing top-level 'name'")?
        .str_()?
        .to_string();
    let word_bits = top
        .get("word_bits")
        .ok_or("missing 'word_bits'")?
        .num()? as u32;
    let mac_energy_pj = top
        .get("mac_energy_pj")
        .map(|v| v.num())
        .transpose()?
        .unwrap_or(1.0);
    let bit_packing = top
        .get("bit_packing")
        .map(|v| v.boolean())
        .transpose()?
        .unwrap_or(true);

    let arch = Arch {
        name,
        word_bits,
        mac_energy_pj,
        levels: levels
            .iter()
            .map(build_level)
            .collect::<Result<Vec<_>, _>>()?,
        bit_packing,
    };
    arch.validate()?;
    Ok(arch)
}

/// Load an architecture spec from a file path.
pub fn load_arch(path: &str) -> Result<Arch, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    parse_arch(&src)
}

/// Render an `Arch` back to its text specification (round-trip support,
/// used to emit the shipped spec files and in tests).
pub fn render_arch(a: &Arch) -> String {
    let mut s = String::new();
    s.push_str(&format!("name = \"{}\"\n", a.name));
    s.push_str(&format!("word_bits = {}\n", a.word_bits));
    s.push_str(&format!("mac_energy_pj = {}\n", a.mac_energy_pj));
    s.push_str(&format!("bit_packing = {}\n", a.bit_packing));
    for l in &a.levels {
        s.push_str("\n[[level]]\n");
        s.push_str(&format!("name = \"{}\"\n", l.name));
        match &l.capacity {
            Capacity::Unbounded => s.push_str("capacity = \"unbounded\"\n"),
            Capacity::Shared(w) => s.push_str(&format!("capacity = {w}\n")),
            Capacity::PerTensor(ws) => s.push_str(&format!(
                "capacity = {{ weights = {}, inputs = {}, outputs = {} }}\n",
                ws[0], ws[1], ws[2]
            )),
        }
        s.push_str(&format!(
            "access_energy_pj = [{}, {}, {}]\n",
            l.access_energy_pj[0], l.access_energy_pj[1], l.access_energy_pj[2]
        ));
        s.push_str(&format!("bandwidth_words = {}\n", l.bandwidth_words));
        s.push_str(&format!("fanout = {}\n", l.fanout));
        if !l.spatial_dims.is_empty() {
            let dims: Vec<String> = l
                .spatial_dims
                .iter()
                .map(|d| format!("\"{}\"", d.name()))
                .collect();
            s.push_str(&format!("spatial_dims = [{}]\n", dims.join(", ")));
        }
        s.push_str(&format!("multicast = {}\n", l.multicast));
        let keeps: Vec<&str> = [("weights", 0), ("inputs", 1), ("outputs", 2)]
            .iter()
            .filter(|&&(_, i)| l.keeps[i])
            .map(|&(n, _)| n)
            .collect();
        let keeps: Vec<String> = keeps.iter().map(|k| format!("\"{k}\"")).collect();
        s.push_str(&format!("keeps = [{}]\n", keeps.join(", ")));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::super::presets;
    use super::*;

    #[test]
    fn roundtrip_presets() {
        for a in [presets::eyeriss(), presets::simba(), presets::toy()] {
            let text = render_arch(&a);
            let parsed = parse_arch(&text).unwrap_or_else(|e| panic!("{}: {e}\n{text}", a.name));
            assert_eq!(parsed, a, "{}", a.name);
        }
    }

    #[test]
    fn parse_minimal_spec() {
        let src = r#"
# tiny accelerator
name = "mini"
word_bits = 16
mac_energy_pj = 1.0

[[level]]
name = "buf"
capacity = 1_024
access_energy_pj = 2.0
fanout = 4
spatial_dims = ["K", "C"]
keeps = ["weights", "inputs", "outputs"]

[[level]]
name = "dram"
capacity = "unbounded"
access_energy_pj = [100, 100, 100]
keeps = ["weights", "inputs", "outputs"]
"#;
        let a = parse_arch(src).unwrap();
        assert_eq!(a.levels.len(), 2);
        assert_eq!(a.levels[0].capacity, Capacity::Shared(1024));
        assert_eq!(a.levels[0].spatial_dims, vec![Dim::K, Dim::C]);
        assert_eq!(a.levels[0].access_energy_pj, [2.0; 3]);
        assert!(a.bit_packing); // default
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse_arch("word_bits = 16").is_err()); // missing name
        assert!(parse_arch("name = \"x\"\nword_bits = 16").is_err()); // no levels
        let bad = "name = \"x\"\nword_bits = 16\n[[level]]\nname = \"a\"\n";
        assert!(parse_arch(bad).is_err()); // level missing fields
    }

    #[test]
    fn timeloop_m_alias() {
        assert_eq!(dim_from_str("M").unwrap(), Dim::K);
    }

    #[test]
    fn comments_and_underscores() {
        let v = parse_value("65_536 # glb words").is_err(); // comment must be stripped by line layer
        assert!(v);
        assert_eq!(parse_value("65_536").unwrap(), Val::Num(65536.0));
    }
}
